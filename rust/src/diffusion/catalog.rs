//! The data catalog: which sites hold a copy of which logical dataset,
//! maintained as one [`CacheModel`] per site plus a deterministic
//! event log.
//!
//! The catalog is the single source of truth both worlds share: the
//! threaded [`crate::karajan::GridScheduler`] drives one keyed by
//! provider site, the simulator's Falkon mode drives one keyed by
//! executor, and the simulator's MultiSite mode drives one keyed by
//! LRM site. Every mutation appends to an ordered [`CacheEvent`] log,
//! which the differential test compares bit for bit between the real
//! and simulated executions.
//!
//! Life cycle of a task at a chosen site:
//!
//! 1. [`DataCatalog::note_task_start`] — each declared input either
//!    *hits* (recency refreshed, copy pinned) or *misses* (staged copy
//!    inserted pinned, possibly evicting LRU residents). Returns
//!    `(hit_bytes, miss_bytes)`; the caller charges staging for the
//!    miss bytes only.
//! 2. [`DataCatalog::note_task_end`] — the attempt finished (success
//!    *or* failure): pins release, deferred evictions apply.
//! 3. [`DataCatalog::record_output`] — on success only: produced
//!    datasets enter the site cache (idempotent for re-records).
//!
//! A vanished site (killed executor) drops its whole cache through
//! [`DataCatalog::drop_site`].
//!
//! A zero-capacity catalog is a strict no-op: every method
//! early-returns, the log stays empty, and no caller behavior changes
//! — which keeps seeded pre-diffusion simulations bit-identical.

use super::cache::CacheModel;
use super::{DatasetId, DatasetRef};

/// One catalog mutation, in operation order. The differential test
/// pins real-vs-sim sequences of these.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheEvent {
    /// A task's declared input was already cached at the chosen site.
    Hit { site: usize, dataset: DatasetId },
    /// A task's declared input was absent: staged in (and cached).
    Miss { site: usize, dataset: DatasetId },
    /// A produced output entered the site cache.
    Output { site: usize, dataset: DatasetId },
    /// An LRU eviction made room for an insert (or ran deferred).
    Evict { site: usize, dataset: DatasetId },
    /// The site vanished (executor failure): copy lost.
    Drop { site: usize, dataset: DatasetId },
}

/// Aggregate catalog counters (bench reporting).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub hit_bytes: u64,
    pub miss_bytes: u64,
}

/// The per-site dataset cache catalog. Pure and clock-free: recency is
/// an internal operation counter, so identical operation sequences
/// yield identical states in both worlds.
#[derive(Debug)]
pub struct DataCatalog {
    capacity: u64,
    caches: Vec<CacheModel>,
    seq: u64,
    log: Vec<CacheEvent>,
    stats: CacheStats,
}

impl DataCatalog {
    /// A catalog of `nsites` sites, each with `capacity_bytes` of
    /// cache. Capacity 0 disables the catalog entirely.
    pub fn new(nsites: usize, capacity_bytes: u64) -> Self {
        Self {
            capacity: capacity_bytes,
            caches: (0..nsites).map(|_| CacheModel::new(capacity_bytes)).collect(),
            seq: 0,
            log: Vec::new(),
            stats: CacheStats::default(),
        }
    }

    /// False for the zero-capacity (disabled) catalog.
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    pub fn sites(&self) -> usize {
        self.caches.len()
    }

    /// Grow the site set to at least `n` (sites/executors register
    /// dynamically; ids are stable indices).
    pub fn ensure_sites(&mut self, n: usize) {
        while self.caches.len() < n {
            self.caches.push(CacheModel::new(self.capacity));
        }
    }

    /// True when `site` holds a copy of `id`.
    pub fn contains(&self, site: usize, id: DatasetId) -> bool {
        self.caches.get(site).map(|c| c.contains(id)).unwrap_or(false)
    }

    /// Bytes of `inputs` already cached at `site` (0 when disabled or
    /// the site is unknown) — the locality signal the router weighs.
    pub fn cached_bytes(&self, site: usize, inputs: &[DatasetRef]) -> u64 {
        let Some(c) = self.caches.get(site) else { return 0 };
        inputs.iter().filter(|d| c.contains(d.id)).map(|d| d.bytes).sum()
    }

    /// A task with declared `inputs` starts at `site`: record hits and
    /// misses, stage+cache the misses, pin everything for the run.
    /// Returns `(hit_bytes, miss_bytes)`.
    pub fn note_task_start(&mut self, site: usize, inputs: &[DatasetRef]) -> (u64, u64) {
        if !self.enabled() || inputs.is_empty() {
            return (0, 0);
        }
        self.ensure_sites(site + 1);
        let (mut hit_bytes, mut miss_bytes) = (0u64, 0u64);
        for d in inputs {
            self.seq += 1;
            let seq = self.seq;
            let (hit, evicted) = {
                let c = &mut self.caches[site];
                if c.contains(d.id) {
                    c.touch(d.id, seq);
                    c.pin(d.id);
                    (true, Vec::new())
                } else {
                    (false, c.insert_pinned(d.id, d.bytes, seq))
                }
            };
            if hit {
                hit_bytes += d.bytes;
                self.stats.hits += 1;
                self.stats.hit_bytes += d.bytes;
                self.log.push(CacheEvent::Hit { site, dataset: d.id });
            } else {
                miss_bytes += d.bytes;
                self.stats.misses += 1;
                self.stats.miss_bytes += d.bytes;
                self.log.push(CacheEvent::Miss { site, dataset: d.id });
                for e in evicted {
                    self.stats.evictions += 1;
                    self.log.push(CacheEvent::Evict { site, dataset: e });
                }
            }
        }
        (hit_bytes, miss_bytes)
    }

    /// The attempt at `site` ended (success or failure): release the
    /// input pins and apply any eviction deferred while they were
    /// held.
    pub fn note_task_end(&mut self, site: usize, inputs: &[DatasetRef]) {
        if !self.enabled() || inputs.is_empty() || site >= self.caches.len() {
            return;
        }
        let evicted = {
            let c = &mut self.caches[site];
            for d in inputs {
                c.unpin(d.id);
            }
            c.sweep()
        };
        for e in evicted {
            self.stats.evictions += 1;
            self.log.push(CacheEvent::Evict { site, dataset: e });
        }
    }

    /// A successful task at `site` produced `outputs`: cache them
    /// (unpinned). Idempotent: a re-record of a resident dataset only
    /// refreshes recency — no event, no growth.
    pub fn record_output(&mut self, site: usize, outputs: &[DatasetRef]) {
        if !self.enabled() || outputs.is_empty() {
            return;
        }
        self.ensure_sites(site + 1);
        for d in outputs {
            self.seq += 1;
            let seq = self.seq;
            let (fresh, evicted) = {
                let c = &mut self.caches[site];
                if c.contains(d.id) {
                    c.touch(d.id, seq);
                    (false, Vec::new())
                } else {
                    (true, c.insert(d.id, d.bytes, seq))
                }
            };
            if fresh {
                self.log.push(CacheEvent::Output { site, dataset: d.id });
                for e in evicted {
                    self.stats.evictions += 1;
                    self.log.push(CacheEvent::Evict { site, dataset: e });
                }
            }
        }
    }

    /// The site vanished (e.g. its executor was killed): every copy it
    /// held is lost, pins included.
    pub fn drop_site(&mut self, site: usize) {
        if !self.enabled() || site >= self.caches.len() {
            return;
        }
        for id in self.caches[site].drop_all() {
            self.log.push(CacheEvent::Drop { site, dataset: id });
        }
    }

    /// The ordered mutation log (the differential-test surface).
    pub fn log(&self) -> &[CacheEvent] {
        &self.log
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds(id: DatasetId, bytes: u64) -> DatasetRef {
        DatasetRef { id, bytes }
    }

    #[test]
    fn zero_capacity_catalog_is_a_strict_noop() {
        let mut cat = DataCatalog::new(2, 0);
        assert!(!cat.enabled());
        assert_eq!(cat.note_task_start(0, &[ds(1, 100)]), (0, 0));
        cat.record_output(0, &[ds(2, 100)]);
        cat.note_task_end(0, &[ds(1, 100)]);
        cat.drop_site(0);
        assert!(cat.log().is_empty(), "disabled catalog logs nothing");
        assert_eq!(cat.stats(), CacheStats::default());
        assert_eq!(cat.cached_bytes(0, &[ds(1, 100)]), 0);
    }

    #[test]
    fn miss_stages_and_caches_then_hits() {
        let mut cat = DataCatalog::new(1, 1000);
        let (h, m) = cat.note_task_start(0, &[ds(7, 100)]);
        assert_eq!((h, m), (0, 100), "cold read is a full miss");
        cat.note_task_end(0, &[ds(7, 100)]);
        let (h, m) = cat.note_task_start(0, &[ds(7, 100)]);
        assert_eq!((h, m), (100, 0), "the staged copy diffused");
        assert_eq!(
            cat.log(),
            &[
                CacheEvent::Miss { site: 0, dataset: 7 },
                CacheEvent::Hit { site: 0, dataset: 7 },
            ]
        );
        let s = cat.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!((s.hit_bytes, s.miss_bytes), (100, 100));
    }

    #[test]
    fn outputs_diffuse_to_the_producing_site_only() {
        let mut cat = DataCatalog::new(2, 1000);
        cat.record_output(1, &[ds(3, 50)]);
        assert!(cat.contains(1, 3));
        assert!(!cat.contains(0, 3));
        assert_eq!(cat.cached_bytes(1, &[ds(3, 50), ds(4, 10)]), 50);
    }

    #[test]
    fn duplicate_record_output_is_idempotent() {
        let mut cat = DataCatalog::new(1, 1000);
        cat.record_output(0, &[ds(3, 50)]);
        let log_len = cat.log().len();
        let stats = cat.stats();
        cat.record_output(0, &[ds(3, 50)]);
        assert_eq!(cat.log().len(), log_len, "re-record logs nothing");
        assert_eq!(cat.stats(), stats);
        assert_eq!(cat.cached_bytes(0, &[ds(3, 50)]), 50);
    }

    #[test]
    fn eviction_pressure_logs_evicts_and_defers_pinned() {
        let mut cat = DataCatalog::new(1, 200);
        cat.record_output(0, &[ds(1, 100)]);
        cat.record_output(0, &[ds(2, 100)]);
        // A running task pins 1; inserting 3 must evict 2 (unpinned),
        // not 1 (older but pinned).
        let (h, m) = cat.note_task_start(0, &[ds(1, 100), ds(3, 100)]);
        assert_eq!((h, m), (100, 100));
        assert!(cat.contains(0, 1), "pinned survivor");
        assert!(!cat.contains(0, 2), "unpinned LRU evicted");
        assert!(cat
            .log()
            .contains(&CacheEvent::Evict { site: 0, dataset: 2 }));
        assert_eq!(cat.stats().evictions, 1);
        cat.note_task_end(0, &[ds(1, 100), ds(3, 100)]);
    }

    #[test]
    fn drop_site_loses_every_copy() {
        let mut cat = DataCatalog::new(2, 1000);
        cat.record_output(0, &[ds(1, 10), ds(2, 10)]);
        cat.record_output(1, &[ds(1, 10)]);
        cat.drop_site(0);
        assert!(!cat.contains(0, 1) && !cat.contains(0, 2));
        assert!(cat.contains(1, 1), "other sites keep their copies");
        assert!(cat.log().ends_with(&[
            CacheEvent::Drop { site: 0, dataset: 1 },
            CacheEvent::Drop { site: 0, dataset: 2 },
        ]));
    }

    #[test]
    fn sites_grow_on_demand() {
        let mut cat = DataCatalog::new(1, 100);
        assert_eq!(cat.sites(), 1);
        cat.record_output(4, &[ds(9, 10)]);
        assert_eq!(cat.sites(), 5);
        assert!(cat.contains(4, 9));
    }
}
