//! Peer-to-peer transfer network for data diffusion (paper §3.13).
//!
//! PR 4's catalog knows *which* sites hold a copy of a dataset, but a
//! miss was still priced as if the only source were the shared
//! filesystem. This module models the missing piece: per-pair
//! site-to-site links plus a planner that, for each miss, picks the
//! cheapest source — a peer already holding the copy, or the shared-FS
//! uplink every site always has.
//!
//! Like the rest of `diffusion/`, everything here is pure and
//! clock-free: the [`LinkTopology`] is a static bandwidth/latency
//! matrix, and [`TransferPlanner::plan`] is a deterministic function of
//! `(destination, bytes, holder set)` that appends the decision to an
//! ordered [`TransferPlan`] log. The threaded `GridScheduler` and the
//! sim driver both drive the same planner, so the differential test
//! (`rust/tests/policy_differential.rs`) pins real-vs-sim plan logs bit
//! for bit. What the *consequences* of a plan cost is consumer-owned:
//! the sim's Falkon mode runs peer fetches as their own fluid channels
//! (`sim::sharedfs::PeerNet`), the sim's MultiSite mode stages picked
//! transfers before GRAM submission, and the real scheduler records the
//! decision only (real transfers take however long they take).
//!
//! The zero-link topology ([`LinkTopology::shared_only`], or simply
//! leaving `DiffusionConfig::links` unset) has no peer links at all:
//! every plan resolves to [`TransferSource::SharedFs`], and every
//! consumer delegates verbatim to the pre-planner shared-FS-only code
//! path, keeping seeded runs bit-identical.

use super::{DatasetId, DatasetRef};
use crate::telemetry::counters::{self, Counter};
use crate::util::time::Micros;

/// One directed-capacity-free link: bandwidth plus a fixed per-transfer
/// latency (connection setup, control round trip).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    /// Link bandwidth in bytes per second.
    pub bandwidth_bps: f64,
    /// Fixed per-transfer latency.
    pub latency: Micros,
}

impl LinkSpec {
    /// A 1 Gb/s link (125 MB/s) with the given latency.
    pub fn gbit(latency: Micros) -> Self {
        Self { bandwidth_bps: 125.0e6, latency }
    }

    /// A 10 Gb/s link (1.25 GB/s) with the given latency.
    pub fn tengbit(latency: Micros) -> Self {
        Self { bandwidth_bps: 1.25e9, latency }
    }

    /// Uncontended transfer-time estimate for `bytes` over this link.
    /// Deterministic: the f64 math is a pure function of the inputs,
    /// so both worlds compute the identical estimate.
    pub fn transfer_us(&self, bytes: u64) -> Micros {
        let secs = bytes as f64 / self.bandwidth_bps.max(1.0);
        self.latency + (secs * 1e6).ceil() as Micros
    }
}

/// The site-to-site link matrix, with the shared filesystem as the
/// default uplink every site can always fall back to.
///
/// Links are symmetric (one entry covers both directions; the fluid
/// consumer shares a link's bandwidth across both directions too) and
/// there is no self-link — a dataset already resident at the
/// destination is a cache hit, not a transfer.
#[derive(Debug, Clone)]
pub struct LinkTopology {
    nsites: usize,
    shared_fs: LinkSpec,
    /// Row-major upper-triangle-mirrored matrix: `links[a * n + b]`.
    links: Vec<Option<LinkSpec>>,
    /// Cached "any peer link exists" flag — consulted on every routed
    /// task, so it must not rescan the n² matrix each time.
    has_peer: bool,
}

impl LinkTopology {
    /// The zero-link topology: every site has only the shared-FS
    /// uplink. Consumers delegate verbatim to the pre-planner
    /// shared-FS-only path, so seeded runs stay bit-identical.
    pub fn shared_only(nsites: usize, shared_fs: LinkSpec) -> Self {
        Self {
            nsites,
            shared_fs,
            links: vec![None; nsites * nsites],
            has_peer: false,
        }
    }

    /// A full mesh: every distinct pair of sites shares one `peer`
    /// link.
    pub fn uniform(nsites: usize, shared_fs: LinkSpec, peer: LinkSpec) -> Self {
        let mut t = Self::shared_only(nsites, shared_fs);
        for a in 0..nsites {
            for b in (a + 1)..nsites {
                t.set_link(a, b, peer);
            }
        }
        t
    }

    /// A star: `hub` is linked to every other site by `spoke`; the
    /// leaves reach each other only through the shared FS.
    pub fn star(nsites: usize, shared_fs: LinkSpec, hub: usize, spoke: LinkSpec) -> Self {
        let mut t = Self::shared_only(nsites, shared_fs);
        for b in 0..nsites {
            if b != hub {
                t.set_link(hub, b, spoke);
            }
        }
        t
    }

    /// Number of sites the matrix covers. Sites beyond it (e.g.
    /// late-registered executors) have no peer links and fall back to
    /// the shared FS.
    pub fn len(&self) -> usize {
        self.nsites
    }

    pub fn is_empty(&self) -> bool {
        self.nsites == 0
    }

    /// The shared-FS uplink spec (the default source of last resort).
    pub fn shared_fs(&self) -> LinkSpec {
        self.shared_fs
    }

    /// Install a symmetric peer link between `a` and `b` (ignored for
    /// self-links or out-of-range sites).
    pub fn set_link(&mut self, a: usize, b: usize, spec: LinkSpec) {
        if a == b || a >= self.nsites || b >= self.nsites {
            return;
        }
        self.links[a * self.nsites + b] = Some(spec);
        self.links[b * self.nsites + a] = Some(spec);
        self.has_peer = true;
    }

    /// The peer link between `a` and `b`, if one exists.
    pub fn link(&self, a: usize, b: usize) -> Option<LinkSpec> {
        if a == b || a >= self.nsites || b >= self.nsites {
            return None;
        }
        self.links[a * self.nsites + b]
    }

    /// True when any peer link exists. False means the topology is
    /// shared-FS-only and consumers take the pre-planner path
    /// verbatim. O(1): cached at construction/`set_link` time because
    /// every routed task consults it.
    pub fn has_peer_links(&self) -> bool {
        self.has_peer
    }
}

/// Where a planned transfer sources its bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferSource {
    /// The shared filesystem (always available).
    SharedFs,
    /// A peer site already holding a copy, over the direct link.
    Peer(usize),
}

/// One planned miss transfer, in decision order. Every field is
/// integral, so plan logs compare exactly — the differential test pins
/// real-vs-sim sequences of these.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferPlan {
    pub dataset: DatasetId,
    /// Site the copy is being staged to.
    pub dest: usize,
    pub source: TransferSource,
    pub bytes: u64,
    /// The planner's uncontended cost estimate for the chosen source.
    pub est_us: Micros,
}

/// The cheapest-source chooser: given a miss at a destination site and
/// the catalog's holder set, pick peer copy vs shared FS and log the
/// deterministic [`TransferPlan`].
///
/// Tie-break is fixed: the shared FS wins an exact cost tie, then the
/// lowest-indexed holder — `holders` must be in ascending site order
/// (which [`super::DataCatalog::holders_of`] guarantees), so identical
/// catalog states plan identically in both worlds.
#[derive(Debug, Clone)]
pub struct TransferPlanner {
    topo: LinkTopology,
    log: Vec<TransferPlan>,
}

impl TransferPlanner {
    pub fn new(topo: LinkTopology) -> Self {
        Self { topo, log: Vec::new() }
    }

    pub fn topology(&self) -> &LinkTopology {
        &self.topo
    }

    /// Cheapest `(source, est_us)` for staging `bytes` to `dest` given
    /// the ascending holder set. Pure; does not log.
    pub fn cheapest(
        &self,
        dest: usize,
        bytes: u64,
        holders: &[usize],
    ) -> (TransferSource, Micros) {
        let mut best = (
            TransferSource::SharedFs,
            self.topo.shared_fs().transfer_us(bytes),
        );
        for &h in holders {
            if h == dest {
                continue;
            }
            if let Some(spec) = self.topo.link(h, dest) {
                let c = spec.transfer_us(bytes);
                if c < best.1 {
                    best = (TransferSource::Peer(h), c);
                }
            }
        }
        best
    }

    /// Uncontended cost estimate of the cheapest source (the router's
    /// weight input). Pure; does not log.
    pub fn estimate(&self, dest: usize, bytes: u64, holders: &[usize]) -> Micros {
        self.cheapest(dest, bytes, holders).1
    }

    /// Plan one miss transfer and append it to the log.
    pub fn plan(
        &mut self,
        dest: usize,
        dataset: DatasetId,
        d_bytes: u64,
        holders: &[usize],
    ) -> TransferPlan {
        let (source, est_us) = self.cheapest(dest, d_bytes, holders);
        match source {
            TransferSource::SharedFs => {
                counters::add(Counter::SharedFsTransferBytes, d_bytes)
            }
            TransferSource::Peer(_) => {
                counters::add(Counter::PeerTransferBytes, d_bytes)
            }
        }
        let p = TransferPlan { dataset, dest, source, bytes: d_bytes, est_us };
        self.log.push(p);
        p
    }

    /// Plan every input of `refs` missing from `dest` (the consumer
    /// computes the deduped miss set via
    /// [`super::DataCatalog::misses_at`] *before* the catalog inserts
    /// them, so holder sets reflect the pre-staging state).
    pub fn plan_misses(
        &mut self,
        catalog: &super::DataCatalog,
        dest: usize,
        misses: &[DatasetRef],
    ) -> Vec<TransferPlan> {
        misses
            .iter()
            .map(|d| {
                let holders = catalog.holders_of(d.id);
                self.plan(dest, d.id, d.bytes, &holders)
            })
            .collect()
    }

    /// The ordered plan log (the differential-test surface).
    pub fn log(&self) -> &[TransferPlan] {
        &self.log
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1024 * 1024;

    fn fs() -> LinkSpec {
        // ~125 MB/s with 30 ms of metadata latency, like the GPFS model.
        LinkSpec::gbit(30_000)
    }

    #[test]
    fn transfer_us_is_latency_plus_bandwidth_time() {
        let l = LinkSpec { bandwidth_bps: 1.0e6, latency: 500 };
        // 2 MB at 1 MB/s = 2 s + 500 us.
        assert_eq!(l.transfer_us(2_000_000), 2_000_000 + 500);
        assert_eq!(l.transfer_us(0), 500, "latency charged even for empty");
    }

    #[test]
    fn shared_only_topology_has_no_peer_links() {
        let t = LinkTopology::shared_only(4, fs());
        assert!(!t.has_peer_links());
        for a in 0..4 {
            for b in 0..4 {
                assert_eq!(t.link(a, b), None);
            }
        }
    }

    #[test]
    fn uniform_links_every_distinct_pair_symmetrically() {
        let t = LinkTopology::uniform(3, fs(), LinkSpec::tengbit(1_000));
        assert!(t.has_peer_links());
        for a in 0..3 {
            assert_eq!(t.link(a, a), None, "no self-links");
            for b in 0..3 {
                if a != b {
                    assert_eq!(t.link(a, b), t.link(b, a));
                    assert!(t.link(a, b).is_some());
                }
            }
        }
    }

    #[test]
    fn star_links_hub_to_leaves_only() {
        let t = LinkTopology::star(4, fs(), 1, LinkSpec::gbit(0));
        assert!(t.link(1, 0).is_some() && t.link(1, 2).is_some());
        assert_eq!(t.link(0, 2), None, "leaves only reach the hub");
        assert_eq!(t.link(2, 3), None);
    }

    #[test]
    fn out_of_range_sites_fall_back_to_shared_fs() {
        let mut t = LinkTopology::uniform(2, fs(), LinkSpec::tengbit(0));
        t.set_link(0, 9, LinkSpec::gbit(0)); // ignored
        assert_eq!(t.link(0, 9), None);
        let p = TransferPlanner::new(t);
        // Holder 9 is outside the matrix: the shared FS wins.
        let (src, _) = p.cheapest(0, MB, &[9]);
        assert_eq!(src, TransferSource::SharedFs);
    }

    #[test]
    fn planner_picks_cheapest_holder_over_shared_fs() {
        let t = LinkTopology::uniform(3, fs(), LinkSpec::tengbit(1_000));
        let mut p = TransferPlanner::new(t);
        let plan = p.plan(0, 42, 64 * MB, &[1, 2]);
        // A dedicated 10 Gb/s peer link beats the 1 Gb/s shared FS;
        // holders are ascending, so the tie between holders 1 and 2
        // (identical links) resolves to the lower index.
        assert_eq!(plan.source, TransferSource::Peer(1));
        assert!(plan.est_us < fs().transfer_us(64 * MB));
        assert_eq!(p.log(), &[plan]);
    }

    #[test]
    fn zero_links_always_plan_shared_fs() {
        let t = LinkTopology::shared_only(3, fs());
        let mut p = TransferPlanner::new(t);
        let plan = p.plan(2, 7, MB, &[0, 1]);
        assert_eq!(plan.source, TransferSource::SharedFs);
        assert_eq!(plan.est_us, fs().transfer_us(MB));
    }

    #[test]
    fn shared_fs_wins_exact_cost_ties() {
        // Peer link identical to the uplink: SharedFs keeps the tie, so
        // the zero-link-equivalent decision is stable.
        let t = LinkTopology::uniform(2, fs(), fs());
        let p = TransferPlanner::new(t);
        let (src, _) = p.cheapest(0, MB, &[1]);
        assert_eq!(src, TransferSource::SharedFs);
    }

    #[test]
    fn holder_at_destination_is_not_a_source() {
        let t = LinkTopology::uniform(2, fs(), LinkSpec::tengbit(0));
        let p = TransferPlanner::new(t);
        let (src, _) = p.cheapest(0, MB, &[0]);
        assert_eq!(src, TransferSource::SharedFs, "self-fetch is meaningless");
    }

    #[test]
    fn plans_are_deterministic_for_identical_inputs() {
        let mk = || {
            let t = LinkTopology::star(4, fs(), 0, LinkSpec::tengbit(2_000));
            let mut p = TransferPlanner::new(t);
            for d in 0..8u64 {
                p.plan((d % 4) as usize, d, (d + 1) * MB, &[0, 2]);
            }
            p.log().to_vec()
        };
        assert_eq!(mk(), mk(), "same inputs, bit-identical plan log");
    }
}
