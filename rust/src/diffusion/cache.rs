//! One site's cache: bounded-capacity LRU residency with
//! pin-while-running semantics.
//!
//! The model is deliberately logical-time: recency is a caller-supplied
//! monotone sequence number (the catalog's operation counter), not a
//! clock, so the same operation sequence produces the same residency
//! state in the threaded runtime and in the simulator — which is what
//! lets the differential test pin eviction trajectories bit for bit.
//!
//! Pinning: a dataset an in-flight task depends on must stay resident
//! for the duration of the run, so eviction of a pinned entry is
//! *deferred* — the cache may temporarily exceed its capacity under pin
//! pressure, and the overdue evictions happen on the next sweep after
//! the pins release. Eviction order is strictly deterministic: least
//! `last_access` first, dataset id as the tie-break.

use std::collections::HashMap;

use super::DatasetId;

#[derive(Debug, Clone)]
struct Entry {
    bytes: u64,
    last_access: u64,
    pins: u32,
}

/// A bounded LRU cache of dataset copies at one site.
#[derive(Debug, Clone)]
pub struct CacheModel {
    capacity: u64,
    used: u64,
    entries: HashMap<DatasetId, Entry>,
}

impl CacheModel {
    pub fn new(capacity: u64) -> Self {
        Self { capacity, used: 0, entries: HashMap::new() }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Bytes currently resident (may exceed capacity under pin
    /// pressure; see the module docs).
    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, id: DatasetId) -> bool {
        self.entries.contains_key(&id)
    }

    /// Refresh recency for a resident dataset. Returns false when the
    /// dataset is not resident.
    pub fn touch(&mut self, id: DatasetId, seq: u64) -> bool {
        match self.entries.get_mut(&id) {
            Some(e) => {
                e.last_access = seq;
                true
            }
            None => false,
        }
    }

    /// Pin a resident dataset (no-op when absent). Pins nest.
    pub fn pin(&mut self, id: DatasetId) {
        if let Some(e) = self.entries.get_mut(&id) {
            e.pins += 1;
        }
    }

    /// Release one pin (no-op when absent or already unpinned). The
    /// caller runs [`CacheModel::sweep`] afterwards to apply any
    /// eviction deferred while the pin was held.
    pub fn unpin(&mut self, id: DatasetId) {
        if let Some(e) = self.entries.get_mut(&id) {
            e.pins = e.pins.saturating_sub(1);
        }
    }

    /// Insert an unpinned copy (a produced output). Idempotent: a
    /// resident dataset only has its recency refreshed — no growth, no
    /// eviction. Returns the datasets evicted to make room, in
    /// eviction order.
    pub fn insert(&mut self, id: DatasetId, bytes: u64, seq: u64) -> Vec<DatasetId> {
        self.insert_with_pins(id, bytes, seq, 0)
    }

    /// Insert a copy pinned once (a staged input of a starting task):
    /// the new entry itself cannot be evicted until the task's
    /// [`CacheModel::unpin`], even when it alone exceeds capacity.
    pub fn insert_pinned(&mut self, id: DatasetId, bytes: u64, seq: u64) -> Vec<DatasetId> {
        self.insert_with_pins(id, bytes, seq, 1)
    }

    fn insert_with_pins(
        &mut self,
        id: DatasetId,
        bytes: u64,
        seq: u64,
        pins: u32,
    ) -> Vec<DatasetId> {
        if let Some(e) = self.entries.get_mut(&id) {
            // Idempotent re-record: recency plus the requested pin. The
            // caller's declared size is authoritative — a dataset whose
            // recorded size changed (e.g. a regenerated output)
            // reconciles `used`, sweeping if the copy grew.
            e.last_access = seq;
            e.pins += pins;
            if e.bytes != bytes {
                let old = e.bytes;
                e.bytes = bytes;
                self.used -= old;
                self.used += bytes;
                if bytes > old {
                    return self.sweep();
                }
            }
            return Vec::new();
        }
        self.entries.insert(id, Entry { bytes, last_access: seq, pins });
        self.used += bytes;
        self.sweep()
    }

    /// Evict least-recently-used unpinned entries until within
    /// capacity. Stops early (deferring) when only pinned entries
    /// remain. Returns evicted ids in eviction order (deterministic:
    /// min `(last_access, id)` first).
    // lint: allow(det-iter) — min_by_key over (last_access, id) is a total
    // order, so the victim is the same for any hash iteration order
    pub fn sweep(&mut self) -> Vec<DatasetId> {
        let mut out = Vec::new();
        while self.used > self.capacity {
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(id, e)| (e.last_access, **id))
                .map(|(id, _)| *id);
            let Some(v) = victim else { break };
            let e = self.entries.remove(&v).expect("victim is resident");
            self.used -= e.bytes;
            out.push(v);
        }
        out
    }

    /// Drop every entry (the site/executor vanished). Returns the
    /// dropped ids sorted (deterministic reporting order).
    // lint: allow(det-iter) — keys are sorted before they leave this fn
    pub fn drop_all(&mut self) -> Vec<DatasetId> {
        let mut ids: Vec<DatasetId> = self.entries.keys().copied().collect();
        ids.sort_unstable();
        self.entries.clear();
        self.used = 0;
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_evicts_least_recent_first() {
        let mut c = CacheModel::new(3);
        assert!(c.insert(1, 1, 1).is_empty());
        assert!(c.insert(2, 1, 2).is_empty());
        assert!(c.insert(3, 1, 3).is_empty());
        // Touch 1: now 2 is the LRU.
        assert!(c.touch(1, 4));
        assert_eq!(c.insert(4, 1, 5), vec![2]);
        assert!(c.contains(1) && c.contains(3) && c.contains(4));
        assert_eq!(c.used(), 3);
    }

    #[test]
    fn eviction_ties_break_on_dataset_id() {
        let mut c = CacheModel::new(2);
        // Same last_access for 7 and 9: the smaller id goes first.
        c.insert(9, 1, 1);
        c.insert(7, 1, 1);
        assert_eq!(c.insert(8, 2, 2), vec![7, 9]);
    }

    #[test]
    fn pinned_entries_defer_eviction() {
        let mut c = CacheModel::new(2);
        c.insert_pinned(1, 1, 1); // oldest, but pinned
        c.insert(2, 1, 2);
        // 3 overflows: the unpinned 2 goes even though 1 is older.
        assert_eq!(c.insert(3, 1, 3), vec![2]);
        assert!(c.contains(1), "pinned entry survived");
        // Still over? No: used == 2 == capacity. Now overflow with
        // everything pinned: eviction defers entirely.
        c.pin(3);
        assert_eq!(c.insert_pinned(4, 1, 4), vec![]);
        assert_eq!(c.used(), 3, "over capacity under pin pressure");
        // Unpinning releases the deferred eviction on the next sweep.
        c.unpin(1);
        assert_eq!(c.sweep(), vec![1]);
        assert_eq!(c.used(), 2);
    }

    #[test]
    fn insert_is_idempotent_for_resident_datasets() {
        let mut c = CacheModel::new(4);
        c.insert(1, 2, 1);
        c.insert(2, 2, 2);
        let before = c.used();
        // Duplicate record: no growth, no eviction, recency refreshed.
        assert!(c.insert(1, 2, 3).is_empty());
        assert_eq!(c.used(), before);
        assert_eq!(c.len(), 2);
        // 1 was refreshed, so 2 is now the LRU.
        assert_eq!(c.insert(3, 2, 4), vec![2]);
    }

    #[test]
    fn rerecord_with_changed_size_reconciles_used() {
        let mut c = CacheModel::new(10);
        c.insert(1, 4, 1);
        c.insert(2, 4, 2);
        assert_eq!(c.used(), 8);
        // Shrink: `used` drops to reality, nothing evicts.
        assert!(c.insert(1, 2, 3).is_empty());
        assert_eq!(c.used(), 6);
        // Grow past capacity: `used` reconciles and the overflow sweeps
        // the LRU (2, since 1 was just refreshed).
        assert_eq!(c.insert(1, 9, 4), vec![2]);
        assert_eq!(c.used(), 9);
        assert_eq!(c.len(), 1);
        // A pinned re-record still reconciles but defers the sweep.
        c.pin(1);
        assert_eq!(c.insert_pinned(1, 12, 5), vec![]);
        assert_eq!(c.used(), 12, "over capacity under pin pressure");
    }

    #[test]
    fn pins_nest() {
        let mut c = CacheModel::new(1);
        c.insert_pinned(1, 1, 1);
        c.pin(1);
        c.insert(2, 1, 2); // overflow; 1 is double-pinned, 2 is newest
        c.unpin(1);
        assert_eq!(c.sweep(), vec![], "one pin still held");
        c.unpin(1);
        assert_eq!(c.sweep(), vec![1], "fully unpinned entry evicts");
    }

    #[test]
    fn oversized_pinned_insert_survives_until_unpin() {
        let mut c = CacheModel::new(1);
        // A dataset larger than the whole cache, pinned by its running
        // task: resident (over capacity) until the task ends.
        assert_eq!(c.insert_pinned(1, 10, 1), vec![]);
        assert!(c.contains(1));
        c.unpin(1);
        assert_eq!(c.sweep(), vec![1], "evicted once the run releases it");
        assert_eq!(c.used(), 0);
    }

    #[test]
    fn drop_all_reports_sorted_and_clears() {
        let mut c = CacheModel::new(10);
        c.insert(5, 1, 1);
        c.insert(1, 1, 2);
        c.insert(3, 1, 3);
        assert_eq!(c.drop_all(), vec![1, 3, 5]);
        assert!(c.is_empty());
        assert_eq!(c.used(), 0);
    }
}
