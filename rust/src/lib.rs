//! # gridswift
//!
//! A from-scratch reproduction of *Realizing Fast, Scalable and Reliable
//! Scientific Computations in Grid Environments* (Zhao et al., CS.DC 2008):
//! the Swift parallel scripting system (SwiftScript + XDTM), the Karajan
//! dataflow execution engine, and the Falkon lightweight task execution
//! service — implemented as a Rust coordinator over AOT-compiled JAX/Pallas
//! compute kernels executed via PJRT.
//!
//! Layer map (see DESIGN.md):
//! - [`swiftscript`] — the workflow language: lexer, parser, XDTM types.
//! - [`xdtm`] — logical datasets, physical mappers.
//! - [`karajan`] — futures, lightweight tasks, dataflow engine, scheduler.
//! - [`falkon`] — queue + streamlined dispatcher + executors + DRP.
//! - [`providers`] — abstract provider interface (local/GRAM/PBS/Falkon).
//! - [`policy`] — clock-agnostic policy core (site scores, DRP sizing,
//!   frame cut-off) shared by the threaded runtime and the simulator.
//! - [`diffusion`] — data diffusion (§3.13): per-site dataset cache
//!   catalog + locality-aware routing, shared by both worlds.
//! - [`sim`] — discrete-event grid simulator (baselines + paper scale).
//! - [`runtime`] — PJRT artifact loading/execution (the compute path).
//! - [`apps`] — fMRI, Montage, MolDyn workloads.
//! - [`provenance`] — Kickstart records + virtual data catalog.
//! - [`telemetry`] — lifecycle spans, counters/histograms, live
//!   scrape snapshots, shared by runtime and sim.
//! - [`check`] — correctness tooling: schedule-exploring concurrency
//!   checker (shadow sync primitives + vector-clock race detector) and
//!   the `pallas-lint` invariant gate.
//! - [`metrics`], [`util`] — timelines, stats, plots, rng, json.

pub mod apps;
pub mod check;
pub mod diffusion;
pub mod falkon;
pub mod karajan;
pub mod metrics;
pub mod xdtm;
pub mod policy;
pub mod provenance;
pub mod providers;
pub mod runtime;
pub mod sim;
pub mod stack;
pub mod swiftscript;
pub mod telemetry;
pub mod util;
