//! Recursive-descent parser for SwiftScript.
//!
//! Disambiguation notes:
//! - At top level, `( ...` starts a procedure declaration (output list).
//! - `type` starts a type declaration.
//! - `Ident Ident ...` is a variable declaration; `Ident . / [ / =`
//!   continues an lvalue for an assignment.
//! - Inside a var declaration, `<` opens a mapper spec (never a
//!   comparison — SwiftScript has no expressions at that position).

use anyhow::{anyhow, bail, Result};

use super::ast::*;
use super::lexer::{Lexer, Token, TokenKind};

pub struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

/// Parse a SwiftScript source into a [`Program`].
pub fn parse(src: &str) -> Result<Program> {
    Parser::new(src)?.program()
}

impl Parser {
    pub fn new(src: &str) -> Result<Self> {
        Ok(Self { toks: Lexer::new(src).tokenize()?, pos: 0 })
    }

    fn peek(&self) -> &TokenKind {
        &self.toks[self.pos].kind
    }

    fn peek_at(&self, off: usize) -> &TokenKind {
        self.toks
            .get(self.pos + off)
            .map(|t| &t.kind)
            .unwrap_or(&TokenKind::Eof)
    }

    fn here(&self) -> String {
        let t = &self.toks[self.pos.min(self.toks.len() - 1)];
        format!("line {}:{} near {:?}", t.line, t.col, t.kind)
    }

    fn bump(&mut self) -> TokenKind {
        let k = self.toks[self.pos].kind.clone();
        if self.pos < self.toks.len() - 1 {
            self.pos += 1;
        }
        k
    }

    fn eat(&mut self, want: TokenKind) -> Result<()> {
        if *self.peek() == want {
            self.bump();
            Ok(())
        } else {
            bail!("expected {want:?} at {}", self.here())
        }
    }

    fn ident(&mut self) -> Result<String> {
        match self.bump() {
            TokenKind::Ident(s) => Ok(s),
            other => bail!("expected identifier, got {other:?} at {}", self.here()),
        }
    }

    // ------------------------------------------------------------------

    pub fn program(&mut self) -> Result<Program> {
        let mut p = Program::default();
        loop {
            match self.peek() {
                TokenKind::Eof => break,
                TokenKind::Type => p.types.push(self.type_decl()?),
                // `( ... ) = ...` is a tuple assignment; `( ... ) name (`
                // is a procedure declaration.
                TokenKind::LParen if self.paren_starts_proc() => {
                    p.procs.push(self.proc_decl()?)
                }
                _ => p.stmts.push(self.statement()?),
            }
        }
        Ok(p)
    }

    /// Lookahead: does the `(` at the cursor open a procedure declaration
    /// (vs a tuple assignment)? Scan to the matching `)` and check the
    /// following token.
    fn paren_starts_proc(&self) -> bool {
        let mut depth = 0usize;
        let mut i = 0usize;
        loop {
            match self.peek_at(i) {
                TokenKind::LParen => depth += 1,
                TokenKind::RParen => {
                    depth -= 1;
                    if depth == 0 {
                        return *self.peek_at(i + 1) != TokenKind::Assign;
                    }
                }
                TokenKind::Eof => return true, // let proc_decl report it
                _ => {}
            }
            i += 1;
        }
    }

    fn type_ref(&mut self) -> Result<TypeRef> {
        let name = self.ident()?;
        let mut depth = 0;
        while *self.peek() == TokenKind::LBracket
            && *self.peek_at(1) == TokenKind::RBracket
        {
            self.bump();
            self.bump();
            depth += 1;
        }
        Ok(TypeRef { name, array_depth: depth })
    }

    fn type_decl(&mut self) -> Result<TypeDecl> {
        self.eat(TokenKind::Type)?;
        let name = self.ident()?;
        self.eat(TokenKind::LBrace)?;
        let mut fields = Vec::new();
        while *self.peek() != TokenKind::RBrace {
            let ty = self.type_ref()?;
            let fname = self.ident()?;
            // Postfix array suffix on the field name: `Volume v[];`
            let mut extra = 0;
            while *self.peek() == TokenKind::LBracket {
                self.bump();
                self.eat(TokenKind::RBracket)?;
                extra += 1;
            }
            self.eat(TokenKind::Semi)?;
            fields.push(FieldDecl {
                ty: TypeRef { name: ty.name, array_depth: ty.array_depth + extra },
                name: fname,
            });
        }
        self.eat(TokenKind::RBrace)?;
        // Optional trailing semicolon.
        if *self.peek() == TokenKind::Semi {
            self.bump();
        }
        Ok(TypeDecl { name, fields })
    }

    fn param_list(&mut self) -> Result<Vec<Param>> {
        let mut out = Vec::new();
        if *self.peek() == TokenKind::RParen {
            return Ok(out);
        }
        loop {
            let ty = self.type_ref()?;
            let name = self.ident()?;
            let mut extra = 0;
            while *self.peek() == TokenKind::LBracket {
                self.bump();
                self.eat(TokenKind::RBracket)?;
                extra += 1;
            }
            out.push(Param {
                ty: TypeRef { name: ty.name, array_depth: ty.array_depth + extra },
                name,
            });
            if *self.peek() == TokenKind::Comma {
                self.bump();
            } else {
                break;
            }
        }
        Ok(out)
    }

    fn proc_decl(&mut self) -> Result<ProcDecl> {
        self.eat(TokenKind::LParen)?;
        let outputs = self.param_list()?;
        self.eat(TokenKind::RParen)?;
        let name = self.ident()?;
        self.eat(TokenKind::LParen)?;
        let inputs = self.param_list()?;
        self.eat(TokenKind::RParen)?;
        self.eat(TokenKind::LBrace)?;
        let body = if *self.peek() == TokenKind::App {
            self.bump();
            self.eat(TokenKind::LBrace)?;
            let spec = self.app_spec()?;
            self.eat(TokenKind::RBrace)?;
            ProcBody::App(spec)
        } else {
            let mut stmts = Vec::new();
            while *self.peek() != TokenKind::RBrace {
                stmts.push(self.statement()?);
            }
            ProcBody::Compound(stmts)
        };
        self.eat(TokenKind::RBrace)?;
        Ok(ProcDecl { name, outputs, inputs, body })
    }

    fn app_spec(&mut self) -> Result<AppSpec> {
        let executable = self.ident()?;
        let mut args = Vec::new();
        while *self.peek() != TokenKind::Semi && *self.peek() != TokenKind::RBrace {
            if *self.peek() == TokenKind::At {
                self.bump();
                let builtin = self.ident()?;
                self.eat(TokenKind::LParen)?;
                let e = self.expr()?;
                self.eat(TokenKind::RParen)?;
                match builtin.as_str() {
                    "filename" => args.push(AppArg::Filename(e)),
                    "filenames" => args.push(AppArg::Filenames(e)),
                    other => bail!("unknown @-builtin @{other} at {}", self.here()),
                }
            } else {
                args.push(AppArg::Expr(self.primary()?));
            }
        }
        if *self.peek() == TokenKind::Semi {
            self.bump();
        }
        Ok(AppSpec { executable, args })
    }

    // ------------------------------------------------------------------
    // Statements
    // ------------------------------------------------------------------

    fn statement(&mut self) -> Result<Stmt> {
        match self.peek() {
            TokenKind::Foreach => self.foreach(),
            TokenKind::If => self.if_stmt(),
            TokenKind::LParen => self.tuple_assign(),
            TokenKind::Ident(_) => {
                // Var decl: `Ident Ident` (a type then a name);
                // otherwise an assignment to an lvalue path.
                let second = self.peek_at(1).clone();
                let is_decl = matches!(second, TokenKind::Ident(_))
                    || (second == TokenKind::LBracket
                        && *self.peek_at(2) == TokenKind::RBracket);
                if is_decl {
                    self.var_decl()
                } else {
                    self.assign()
                }
            }
            _ => bail!("unexpected token at {}", self.here()),
        }
    }

    fn var_decl(&mut self) -> Result<Stmt> {
        let ty = self.type_ref()?;
        let name = self.ident()?;
        // Postfix array suffix: `DiffStruct diffs[]<csv_mapper;...>`
        let mut extra = 0;
        while *self.peek() == TokenKind::LBracket
            && *self.peek_at(1) == TokenKind::RBracket
        {
            self.bump();
            self.bump();
            extra += 1;
        }
        let ty = TypeRef { name: ty.name, array_depth: ty.array_depth + extra };
        let mapper = if *self.peek() == TokenKind::Lt {
            Some(self.mapper_spec()?)
        } else {
            None
        };
        let init = if *self.peek() == TokenKind::Assign {
            self.bump();
            Some(self.expr()?)
        } else {
            None
        };
        self.eat(TokenKind::Semi)?;
        Ok(Stmt::VarDecl { ty, name, mapper, init })
    }

    fn mapper_spec(&mut self) -> Result<MapperSpec> {
        self.eat(TokenKind::Lt)?;
        let mapper = self.ident()?;
        let mut params = Vec::new();
        if *self.peek() == TokenKind::Semi {
            self.bump();
            loop {
                let key = self.ident()?;
                self.eat(TokenKind::Assign)?;
                let val = match self.peek().clone() {
                    TokenKind::Str(s) => {
                        self.bump();
                        Expr::Str(s)
                    }
                    TokenKind::Int(i) => {
                        self.bump();
                        Expr::Int(i)
                    }
                    TokenKind::Float(f) => {
                        self.bump();
                        Expr::Float(f)
                    }
                    TokenKind::True => {
                        self.bump();
                        Expr::Bool(true)
                    }
                    TokenKind::False => {
                        self.bump();
                        Expr::Bool(false)
                    }
                    TokenKind::Ident(_) => Expr::Path(self.lvalue()?),
                    other => bail!(
                        "bad mapper parameter value {other:?} at {}",
                        self.here()
                    ),
                };
                params.push((key, val));
                if *self.peek() == TokenKind::Comma {
                    self.bump();
                } else {
                    break;
                }
            }
        }
        self.eat(TokenKind::Gt)?;
        Ok(MapperSpec { mapper, params })
    }

    fn assign(&mut self) -> Result<Stmt> {
        let lhs = self.lvalue()?;
        self.eat(TokenKind::Assign)?;
        let rhs = self.expr()?;
        self.eat(TokenKind::Semi)?;
        Ok(Stmt::Assign { lhs, rhs })
    }

    fn tuple_assign(&mut self) -> Result<Stmt> {
        self.eat(TokenKind::LParen)?;
        let mut lhs = Vec::new();
        loop {
            lhs.push(self.lvalue()?);
            if *self.peek() == TokenKind::Comma {
                self.bump();
            } else {
                break;
            }
        }
        self.eat(TokenKind::RParen)?;
        self.eat(TokenKind::Assign)?;
        let rhs = self.expr()?;
        self.eat(TokenKind::Semi)?;
        Ok(Stmt::TupleAssign { lhs, rhs })
    }

    fn foreach(&mut self) -> Result<Stmt> {
        self.eat(TokenKind::Foreach)?;
        // Optional element type: `foreach Volume iv, i in run.v`.
        let (elem_ty, var) = {
            let first = self.ident()?;
            if let TokenKind::Ident(_) = self.peek() {
                let v = self.ident()?;
                (Some(TypeRef::simple(&first)), v)
            } else {
                (None, first)
            }
        };
        let index = if *self.peek() == TokenKind::Comma {
            self.bump();
            Some(self.ident()?)
        } else {
            None
        };
        self.eat(TokenKind::In)?;
        let over = self.expr()?;
        self.eat(TokenKind::LBrace)?;
        let mut body = Vec::new();
        while *self.peek() != TokenKind::RBrace {
            body.push(self.statement()?);
        }
        self.eat(TokenKind::RBrace)?;
        Ok(Stmt::Foreach { elem_ty, var, index, over, body })
    }

    fn if_stmt(&mut self) -> Result<Stmt> {
        self.eat(TokenKind::If)?;
        self.eat(TokenKind::LParen)?;
        let cond = self.expr()?;
        self.eat(TokenKind::RParen)?;
        self.eat(TokenKind::LBrace)?;
        let mut then_body = Vec::new();
        while *self.peek() != TokenKind::RBrace {
            then_body.push(self.statement()?);
        }
        self.eat(TokenKind::RBrace)?;
        let mut else_body = Vec::new();
        if *self.peek() == TokenKind::Else {
            self.bump();
            self.eat(TokenKind::LBrace)?;
            while *self.peek() != TokenKind::RBrace {
                else_body.push(self.statement()?);
            }
            self.eat(TokenKind::RBrace)?;
        }
        Ok(Stmt::If { cond, then_body, else_body })
    }

    // ------------------------------------------------------------------
    // Expressions (precedence: comparison < additive < multiplicative)
    // ------------------------------------------------------------------

    fn expr(&mut self) -> Result<Expr> {
        let lhs = self.additive()?;
        let op = match self.peek() {
            TokenKind::Eq => BinOp::Eq,
            TokenKind::Ne => BinOp::Ne,
            TokenKind::Lt => BinOp::Lt,
            TokenKind::Le => BinOp::Le,
            TokenKind::Gt => BinOp::Gt,
            TokenKind::Ge => BinOp::Ge,
            _ => return Ok(lhs),
        };
        self.bump();
        let rhs = self.additive()?;
        Ok(Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) })
    }

    fn additive(&mut self) -> Result<Expr> {
        let mut lhs = self.multiplicative()?;
        loop {
            let op = match self.peek() {
                TokenKind::Plus => BinOp::Add,
                TokenKind::Minus => BinOp::Sub,
                _ => return Ok(lhs),
            };
            self.bump();
            let rhs = self.multiplicative()?;
            lhs = Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) };
        }
    }

    fn multiplicative(&mut self) -> Result<Expr> {
        let mut lhs = self.primary()?;
        loop {
            let op = match self.peek() {
                TokenKind::Star => BinOp::Mul,
                TokenKind::Slash => BinOp::Div,
                _ => return Ok(lhs),
            };
            self.bump();
            let rhs = self.primary()?;
            lhs = Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) };
        }
    }

    fn primary(&mut self) -> Result<Expr> {
        match self.peek().clone() {
            TokenKind::Int(i) => {
                self.bump();
                Ok(Expr::Int(i))
            }
            TokenKind::Float(f) => {
                self.bump();
                Ok(Expr::Float(f))
            }
            TokenKind::Str(s) => {
                self.bump();
                Ok(Expr::Str(s))
            }
            TokenKind::True => {
                self.bump();
                Ok(Expr::Bool(true))
            }
            TokenKind::False => {
                self.bump();
                Ok(Expr::Bool(false))
            }
            TokenKind::Minus => {
                self.bump();
                match self.bump() {
                    TokenKind::Int(i) => Ok(Expr::Int(-i)),
                    TokenKind::Float(f) => Ok(Expr::Float(-f)),
                    other => bail!("bad negation of {other:?} at {}", self.here()),
                }
            }
            TokenKind::LParen => {
                self.bump();
                let e = self.expr()?;
                self.eat(TokenKind::RParen)?;
                Ok(e)
            }
            TokenKind::Ident(_) => {
                // Call or path.
                if *self.peek_at(1) == TokenKind::LParen {
                    let name = self.ident()?;
                    self.eat(TokenKind::LParen)?;
                    let mut args = Vec::new();
                    if *self.peek() != TokenKind::RParen {
                        loop {
                            args.push(self.expr()?);
                            if *self.peek() == TokenKind::Comma {
                                self.bump();
                            } else {
                                break;
                            }
                        }
                    }
                    self.eat(TokenKind::RParen)?;
                    Ok(Expr::Call { name, args })
                } else {
                    Ok(Expr::Path(self.lvalue()?))
                }
            }
            other => Err(anyhow!("unexpected {other:?} at {}", self.here())),
        }
    }

    fn lvalue(&mut self) -> Result<LValue> {
        let base = self.ident()?;
        let mut path = Vec::new();
        loop {
            match self.peek() {
                TokenKind::Dot => {
                    self.bump();
                    path.push(Access::Member(self.ident()?));
                }
                TokenKind::LBracket => {
                    self.bump();
                    let idx = self.expr()?;
                    self.eat(TokenKind::RBracket)?;
                    path.push(Access::Index(idx));
                }
                _ => return Ok(LValue { base, path }),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Figure 1 fMRI workflow, verbatim modulo whitespace.
    pub const FMRI_FIG1: &str = r#"
type Image {};
type Header {};
type Volume { Image img; Header hdr; };
type Run { Volume v[]; };
type Air {};
type AirVector { Air a[]; };

(Volume ov) reorient (Volume iv, string direction, string overwrite)
{
  app {
    reorient @filename(iv.hdr) @filename(ov.hdr) direction overwrite;
  }
}
(Run or) reorientRun (Run ir, string direction, string overwrite)
{
  foreach Volume iv, i in ir.v {
    or.v[i] = reorient(iv, direction, overwrite);
  }
}
(Run resliced) fmri_wf (Run r) {
  Run yroRun = reorientRun( r, "y", "n" );
  Run roRun = reorientRun( yroRun, "x", "n" );
  Volume std = roRun.v[1];
  AirVector roAirVec = alignlinearRun(std, roRun, 12, 1000, 1000, "81 3 3");
  resliced = resliceRun( roRun, roAirVec, "-o", "-k");
}
Run bold1<run_mapper;location="fmridc/functional_data/",prefix="bold1">;
Run sbold1<run_mapper;location="fmridc/functional_data/",prefix="sbold1">;
sbold1 = fmri_wf(bold1);
"#;

    #[test]
    fn parses_paper_figure1() {
        let p = parse(FMRI_FIG1).unwrap();
        assert_eq!(p.types.len(), 6);
        assert_eq!(p.procs.len(), 3);
        assert_eq!(p.stmts.len(), 3);
        // reorient is atomic with 4 command args.
        let reorient = &p.procs[0];
        assert_eq!(reorient.name, "reorient");
        match &reorient.body {
            ProcBody::App(spec) => {
                assert_eq!(spec.executable, "reorient");
                assert_eq!(spec.args.len(), 4);
                assert!(matches!(spec.args[0], AppArg::Filename(_)));
                assert!(matches!(spec.args[2], AppArg::Expr(_)));
            }
            _ => panic!("reorient must be atomic"),
        }
        // reorientRun iterates with an index variable.
        match &p.procs[1].body {
            ProcBody::Compound(stmts) => match &stmts[0] {
                Stmt::Foreach { var, index, elem_ty, .. } => {
                    assert_eq!(var, "iv");
                    assert_eq!(index.as_deref(), Some("i"));
                    assert_eq!(elem_ty.as_ref().unwrap().name, "Volume");
                }
                other => panic!("expected foreach, got {other:?}"),
            },
            _ => panic!(),
        }
    }

    #[test]
    fn parses_run_type_with_array_field() {
        let p = parse("type Run { Volume v[]; };").unwrap();
        assert_eq!(p.types[0].fields[0].ty.array_depth, 1);
        assert_eq!(p.types[0].fields[0].name, "v");
    }

    #[test]
    fn parses_mapper_with_variable_reference() {
        // Montage Figure 3: file=diffsTbl references a dataset variable.
        let src = r#"
type Image {};
type DiffStruct { int cntr1; int cntr2; Image plus; Image minus; Image diff; };
Table diffsTbl = mOverlaps(projImgTbl);
DiffStruct diffs[]<csv_mapper; file=diffsTbl, skip=1, header=true, hdelim="|">;
"#;
        let p = parse(src).unwrap();
        match &p.stmts[1] {
            Stmt::VarDecl { ty, mapper: Some(m), .. } => {
                assert_eq!(ty.array_depth, 1);
                assert_eq!(m.mapper, "csv_mapper");
                assert_eq!(m.params.len(), 4);
                assert!(matches!(m.params[0].1, Expr::Path(_)));
                assert_eq!(m.params[1].1, Expr::Int(1));
                assert_eq!(m.params[2].1, Expr::Bool(true));
                assert_eq!(m.params[3].1, Expr::Str("|".into()));
            }
            other => panic!("expected mapped decl, got {other:?}"),
        }
    }

    #[test]
    fn parses_foreach_without_type_or_index() {
        let p = parse("foreach d in diffs { Image i1 = d.plus; }").unwrap();
        match &p.stmts[0] {
            Stmt::Foreach { var, index, elem_ty, .. } => {
                assert_eq!(var, "d");
                assert!(index.is_none());
                assert!(elem_ty.is_none());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parses_if_else_and_comparisons() {
        let src = r#"
if (n > 100) {
  mosaic = coaddRegions(imgs, 8);
} else {
  mosaic = coadd(imgs);
}
"#;
        let p = parse(src).unwrap();
        match &p.stmts[0] {
            Stmt::If { cond, then_body, else_body } => {
                assert!(matches!(
                    cond,
                    Expr::Binary { op: BinOp::Gt, .. }
                ));
                assert_eq!(then_body.len(), 1);
                assert_eq!(else_body.len(), 1);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parses_tuple_assign() {
        let p = parse("(resliced, params) = fmri_chain(v, r);").unwrap();
        match &p.stmts[0] {
            Stmt::TupleAssign { lhs, .. } => {
                assert_eq!(lhs.len(), 2);
                assert_eq!(lhs[0].base, "resliced");
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parses_arithmetic_precedence() {
        let p = parse("int x = 1 + 2 * 3;").unwrap();
        match &p.stmts[0] {
            Stmt::VarDecl { init: Some(Expr::Binary { op: BinOp::Add, rhs, .. }), .. } => {
                assert!(matches!(**rhs, Expr::Binary { op: BinOp::Mul, .. }));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("type { }").is_err());
        assert!(parse("foreach in x { }").is_err());
        assert!(parse("x = ;").is_err());
        assert!(parse("(a,b = f(x);").is_err());
    }

    #[test]
    fn negative_literals() {
        let p = parse("int x = -5; float y = -2.5;").unwrap();
        assert!(matches!(
            p.stmts[0],
            Stmt::VarDecl { init: Some(Expr::Int(-5)), .. }
        ));
    }
}
