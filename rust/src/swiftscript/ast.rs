//! SwiftScript abstract syntax tree.

/// A type reference as written: name + array suffix count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TypeRef {
    pub name: String,
    /// Number of `[]` suffixes.
    pub array_depth: usize,
}

impl TypeRef {
    pub fn simple(name: &str) -> Self {
        Self { name: name.to_string(), array_depth: 0 }
    }
}

/// A struct field declaration.
#[derive(Debug, Clone, PartialEq)]
pub struct FieldDecl {
    pub ty: TypeRef,
    pub name: String,
}

/// `type Name { fields }` (empty fields = opaque file type).
#[derive(Debug, Clone, PartialEq)]
pub struct TypeDecl {
    pub name: String,
    pub fields: Vec<FieldDecl>,
}

/// Procedure parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    pub ty: TypeRef,
    pub name: String,
}

/// One argument in an `app { ... }` command line.
#[derive(Debug, Clone, PartialEq)]
pub enum AppArg {
    /// `@filename(expr)` — physical path of a mapped dataset.
    Filename(Expr),
    /// `@filenames(expr)` — all physical paths of a dataset collection,
    /// rendered as consecutive command-line words.
    Filenames(Expr),
    /// Any expression rendered to a command-line word.
    Expr(Expr),
}

/// `app { executable arg arg ...; }`.
#[derive(Debug, Clone, PartialEq)]
pub struct AppSpec {
    pub executable: String,
    pub args: Vec<AppArg>,
}

/// Procedure body: atomic (app) or compound (statements).
#[derive(Debug, Clone, PartialEq)]
pub enum ProcBody {
    App(AppSpec),
    Compound(Vec<Stmt>),
}

/// `(outputs) name (inputs) { body }`.
#[derive(Debug, Clone, PartialEq)]
pub struct ProcDecl {
    pub name: String,
    pub outputs: Vec<Param>,
    pub inputs: Vec<Param>,
    pub body: ProcBody,
}

/// Mapper declaration: `<mapper_name; key=value, ...>`.
#[derive(Debug, Clone, PartialEq)]
pub struct MapperSpec {
    pub mapper: String,
    /// Values are expressions: literals or dataset references (the
    /// montage `file=diffsTbl` case).
    pub params: Vec<(String, Expr)>,
}

/// lvalue path element.
#[derive(Debug, Clone, PartialEq)]
pub enum Access {
    Member(String),
    Index(Expr),
}

/// `base.member[index]...`
#[derive(Debug, Clone, PartialEq)]
pub struct LValue {
    pub base: String,
    pub path: Vec<Access>,
}

impl LValue {
    pub fn var(name: &str) -> Self {
        Self { base: name.to_string(), path: Vec::new() }
    }
}

/// Binary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

/// Expressions.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Int(i64),
    Float(f64),
    Str(String),
    Bool(bool),
    Path(LValue),
    Call { name: String, args: Vec<Expr> },
    Binary { op: BinOp, lhs: Box<Expr>, rhs: Box<Expr> },
}

/// Statements.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// `Type name<mapper;...> = init;` (mapper and init optional).
    VarDecl {
        ty: TypeRef,
        name: String,
        mapper: Option<MapperSpec>,
        init: Option<Expr>,
    },
    /// `lhs = expr;`
    Assign { lhs: LValue, rhs: Expr },
    /// `(a, b) = call(...);` — multi-output procedure call.
    TupleAssign { lhs: Vec<LValue>, rhs: Expr },
    /// `foreach [Type] v[, i] in over { body }`
    Foreach {
        elem_ty: Option<TypeRef>,
        var: String,
        index: Option<String>,
        over: Expr,
        body: Vec<Stmt>,
    },
    /// `if (cond) { .. } [else { .. }]`
    If {
        cond: Expr,
        then_body: Vec<Stmt>,
        else_body: Vec<Stmt>,
    },
}

/// A parsed program.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Program {
    pub types: Vec<TypeDecl>,
    pub procs: Vec<ProcDecl>,
    pub stmts: Vec<Stmt>,
}
