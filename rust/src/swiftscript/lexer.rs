//! SwiftScript lexer: hand-written, line/column tracked, `//` and `#`
//! line comments.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TokenKind {
    Ident(String),
    Int(i64),
    Float(f64),
    Str(String),
    // Keywords.
    Type,
    App,
    Foreach,
    In,
    If,
    Else,
    True,
    False,
    // Punctuation.
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Lt,
    Gt,
    Semi,
    Comma,
    Dot,
    At,
    Assign,
    // Operators.
    Eq,
    Ne,
    Le,
    Ge,
    Plus,
    Minus,
    Star,
    Slash,
    Eof,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub kind: TokenKind,
    pub line: usize,
    pub col: usize,
}

pub struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: usize,
    col: usize,
}

impl<'a> Lexer<'a> {
    pub fn new(src: &'a str) -> Self {
        Self { src: src.as_bytes(), pos: 0, line: 1, col: 1 }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn peek2(&self) -> Option<u8> {
        self.src.get(self.pos + 1).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        if c == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }

    fn skip_trivia(&mut self) {
        loop {
            match self.peek() {
                Some(c) if c.is_ascii_whitespace() => {
                    self.bump();
                }
                Some(b'/') if self.peek2() == Some(b'/') => {
                    while let Some(c) = self.peek() {
                        if c == b'\n' {
                            break;
                        }
                        self.bump();
                    }
                }
                Some(b'#') => {
                    while let Some(c) = self.peek() {
                        if c == b'\n' {
                            break;
                        }
                        self.bump();
                    }
                }
                _ => break,
            }
        }
    }

    fn tok(&self, kind: TokenKind, line: usize, col: usize) -> Token {
        Token { kind, line, col }
    }

    pub fn next_token(&mut self) -> Result<Token> {
        self.skip_trivia();
        let (line, col) = (self.line, self.col);
        let Some(c) = self.peek() else {
            return Ok(self.tok(TokenKind::Eof, line, col));
        };
        // Identifiers / keywords.
        if c.is_ascii_alphabetic() || c == b'_' {
            let start = self.pos;
            while let Some(c) = self.peek() {
                if c.is_ascii_alphanumeric() || c == b'_' {
                    self.bump();
                } else {
                    break;
                }
            }
            let word = std::str::from_utf8(&self.src[start..self.pos])?.to_string();
            let kind = match word.as_str() {
                "type" => TokenKind::Type,
                "app" => TokenKind::App,
                "foreach" => TokenKind::Foreach,
                "in" => TokenKind::In,
                "if" => TokenKind::If,
                "else" => TokenKind::Else,
                "true" => TokenKind::True,
                "false" => TokenKind::False,
                _ => TokenKind::Ident(word),
            };
            return Ok(self.tok(kind, line, col));
        }
        // Numbers.
        if c.is_ascii_digit() {
            let start = self.pos;
            let mut is_float = false;
            while let Some(c) = self.peek() {
                if c.is_ascii_digit() {
                    self.bump();
                } else if c == b'.'
                    && self.peek2().map(|d| d.is_ascii_digit()).unwrap_or(false)
                    && !is_float
                {
                    is_float = true;
                    self.bump();
                } else {
                    break;
                }
            }
            let text = std::str::from_utf8(&self.src[start..self.pos])?;
            let kind = if is_float {
                TokenKind::Float(text.parse()?)
            } else {
                TokenKind::Int(text.parse()?)
            };
            return Ok(self.tok(kind, line, col));
        }
        // Strings.
        if c == b'"' {
            self.bump();
            let mut s = String::new();
            loop {
                match self.bump() {
                    Some(b'"') => break,
                    Some(b'\\') => match self.bump() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        other => bail!(
                            "line {line}: bad escape \\{:?} in string",
                            other.map(|c| c as char)
                        ),
                    },
                    Some(c) => s.push(c as char),
                    None => bail!("line {line}: unterminated string"),
                }
            }
            return Ok(self.tok(TokenKind::Str(s), line, col));
        }
        // Operators / punctuation.
        self.bump();
        let two = |l: &mut Self, k: TokenKind| -> Result<Token> {
            l.bump();
            Ok(Token { kind: k, line, col })
        };
        match c {
            b'(' => Ok(self.tok(TokenKind::LParen, line, col)),
            b')' => Ok(self.tok(TokenKind::RParen, line, col)),
            b'{' => Ok(self.tok(TokenKind::LBrace, line, col)),
            b'}' => Ok(self.tok(TokenKind::RBrace, line, col)),
            b'[' => Ok(self.tok(TokenKind::LBracket, line, col)),
            b']' => Ok(self.tok(TokenKind::RBracket, line, col)),
            b';' => Ok(self.tok(TokenKind::Semi, line, col)),
            b',' => Ok(self.tok(TokenKind::Comma, line, col)),
            b'.' => Ok(self.tok(TokenKind::Dot, line, col)),
            b'@' => Ok(self.tok(TokenKind::At, line, col)),
            b'+' => Ok(self.tok(TokenKind::Plus, line, col)),
            b'-' => Ok(self.tok(TokenKind::Minus, line, col)),
            b'*' => Ok(self.tok(TokenKind::Star, line, col)),
            b'/' => Ok(self.tok(TokenKind::Slash, line, col)),
            b'=' if self.peek() == Some(b'=') => two(self, TokenKind::Eq),
            b'=' => Ok(self.tok(TokenKind::Assign, line, col)),
            b'!' if self.peek() == Some(b'=') => two(self, TokenKind::Ne),
            b'<' if self.peek() == Some(b'=') => two(self, TokenKind::Le),
            b'<' => Ok(self.tok(TokenKind::Lt, line, col)),
            b'>' if self.peek() == Some(b'=') => two(self, TokenKind::Ge),
            b'>' => Ok(self.tok(TokenKind::Gt, line, col)),
            other => bail!("line {line}:{col}: unexpected character {:?}", other as char),
        }
    }

    /// Lex the whole input.
    pub fn tokenize(mut self) -> Result<Vec<Token>> {
        let mut out = Vec::new();
        loop {
            let t = self.next_token()?;
            let eof = t.kind == TokenKind::Eof;
            out.push(t);
            if eof {
                return Ok(out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokenKind> {
        Lexer::new(src)
            .tokenize()
            .unwrap()
            .into_iter()
            .map(|t| t.kind)
            .collect()
    }

    #[test]
    fn lexes_type_decl() {
        let k = kinds("type Volume { Image img; }");
        assert_eq!(
            k,
            vec![
                TokenKind::Type,
                TokenKind::Ident("Volume".into()),
                TokenKind::LBrace,
                TokenKind::Ident("Image".into()),
                TokenKind::Ident("img".into()),
                TokenKind::Semi,
                TokenKind::RBrace,
                TokenKind::Eof,
            ]
        );
    }

    #[test]
    fn lexes_mapper_decl_with_strings() {
        let k = kinds(r#"Run b<run_mapper;location="d/",prefix="bold1">;"#);
        assert!(k.contains(&TokenKind::Lt));
        assert!(k.contains(&TokenKind::Str("d/".into())));
        assert!(k.contains(&TokenKind::Gt));
    }

    #[test]
    fn lexes_numbers_and_operators() {
        let k = kinds("x = 12 + 3.5 * 2; y == 4; z != 1; a <= 2; b >= 3");
        assert!(k.contains(&TokenKind::Int(12)));
        assert!(k.contains(&TokenKind::Float(3.5)));
        assert!(k.contains(&TokenKind::Eq));
        assert!(k.contains(&TokenKind::Ne));
        assert!(k.contains(&TokenKind::Le));
        assert!(k.contains(&TokenKind::Ge));
    }

    #[test]
    fn skips_comments_both_styles() {
        let k = kinds("// swift comment\n# hash comment\nfoo");
        assert_eq!(k, vec![TokenKind::Ident("foo".into()), TokenKind::Eof]);
    }

    #[test]
    fn at_filename_builtin() {
        let k = kinds("@filename(iv.hdr)");
        assert_eq!(k[0], TokenKind::At);
        assert_eq!(k[1], TokenKind::Ident("filename".into()));
    }

    #[test]
    fn string_escapes() {
        let k = kinds(r#""a\"b\n""#);
        assert_eq!(k[0], TokenKind::Str("a\"b\n".into()));
    }

    #[test]
    fn errors_on_unterminated_string() {
        assert!(Lexer::new("\"abc").tokenize().is_err());
    }

    #[test]
    fn tracks_line_numbers() {
        let toks = Lexer::new("a\nb\n  c").tokenize().unwrap();
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 2);
        assert_eq!(toks[2].line, 3);
        assert_eq!(toks[2].col, 3);
    }
}
