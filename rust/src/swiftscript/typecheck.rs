//! SwiftScript type checker (paper §3.12: "type checking capabilities
//! allow it to identify potential problems in a program prior to
//! execution").
//!
//! Builds the XDTM [`TypeEnv`] from the program's type declarations,
//! registers procedure signatures, and checks every statement and
//! expression. The result, [`TypedProgram`], is the "abstract computation
//! plan" the Karajan engine interprets.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::ast::*;
use crate::xdtm::types::{StructDef, Type, TypeEnv};

/// A checked program, ready for the engine.
#[derive(Debug, Clone)]
pub struct TypedProgram {
    pub env: TypeEnv,
    pub procs: BTreeMap<String, ProcDecl>,
    pub globals: Vec<Stmt>,
    /// Types of global variables (declaration order preserved in globals).
    pub global_types: BTreeMap<String, Type>,
}

/// Internal expression type: single value or a procedure's output tuple.
#[derive(Debug, Clone, PartialEq)]
enum ETy {
    One(Type),
    Tuple(Vec<Type>),
}

impl ETy {
    fn one(self) -> Result<Type> {
        match self {
            ETy::One(t) => Ok(t),
            ETy::Tuple(ts) => bail!(
                "expected a single value, got a {}-output procedure result",
                ts.len()
            ),
        }
    }
}

struct Scope {
    frames: Vec<BTreeMap<String, Type>>,
}

impl Scope {
    fn push(&mut self) {
        self.frames.push(BTreeMap::new());
    }

    fn pop(&mut self) {
        self.frames.pop();
    }

    fn declare(&mut self, name: &str, ty: Type) -> Result<()> {
        let top = self.frames.last_mut().unwrap();
        if top.contains_key(name) {
            bail!("variable {name} already declared in this scope");
        }
        top.insert(name.to_string(), ty);
        Ok(())
    }

    fn lookup(&self, name: &str) -> Result<Type> {
        for frame in self.frames.iter().rev() {
            if let Some(t) = frame.get(name) {
                return Ok(t.clone());
            }
        }
        bail!("undeclared variable {name}")
    }
}

struct Checker {
    env: TypeEnv,
    procs: BTreeMap<String, ProcDecl>,
}

/// Run the type checker over a parsed program.
pub fn typecheck(p: Program) -> Result<TypedProgram> {
    // Pass 1: type declarations, in order (forward references rejected,
    // matching the paper's examples which declare bottom-up).
    let mut env = TypeEnv::new();
    for td in &p.types {
        if td.fields.is_empty() {
            env.declare_file(&td.name)?;
        } else {
            let mut fields = Vec::new();
            for f in &td.fields {
                let base = env.resolve(&f.ty.name)
                    .map_err(|e| anyhow!("in type {}: {e}", td.name))?;
                fields.push((f.name.clone(), apply_depth(base, f.ty.array_depth)));
            }
            env.declare_struct(&td.name, StructDef { fields })?;
        }
    }
    // Pass 2: procedure signatures.
    let mut procs = BTreeMap::new();
    for proc in &p.procs {
        if procs.contains_key(&proc.name) {
            bail!("procedure {} declared twice", proc.name);
        }
        if proc.outputs.is_empty() {
            bail!("procedure {} has no outputs (procedures are functional)", proc.name);
        }
        procs.insert(proc.name.clone(), proc.clone());
    }
    let checker = Checker { env, procs };
    // Pass 3: procedure bodies.
    for proc in checker.procs.values() {
        checker.check_proc(proc)?;
    }
    // Pass 4: global statements.
    let mut scope = Scope { frames: vec![BTreeMap::new()] };
    for stmt in &p.stmts {
        checker.check_stmt(stmt, &mut scope)?;
    }
    let global_types = scope.frames.pop().unwrap();
    Ok(TypedProgram {
        env: checker.env,
        procs: checker.procs,
        globals: p.stmts,
        global_types,
    })
}

fn apply_depth(base: Type, depth: usize) -> Type {
    let mut t = base;
    for _ in 0..depth {
        t = Type::array_of(t);
    }
    t
}

fn assignable(dst: &Type, src: &Type) -> bool {
    dst == src || (matches!(dst, Type::Float) && matches!(src, Type::Int))
}

impl Checker {
    fn resolve_ref(&self, r: &TypeRef) -> Result<Type> {
        Ok(apply_depth(self.env.resolve(&r.name)?, r.array_depth))
    }

    fn check_proc(&self, proc: &ProcDecl) -> Result<()> {
        let mut scope = Scope { frames: vec![BTreeMap::new()] };
        for p in proc.inputs.iter().chain(&proc.outputs) {
            scope
                .declare(&p.name, self.resolve_ref(&p.ty)?)
                .map_err(|e| anyhow!("in {}: {e}", proc.name))?;
        }
        match &proc.body {
            ProcBody::App(spec) => {
                for arg in &spec.args {
                    match arg {
                        AppArg::Filename(e) => {
                            let t = self.check_expr(e, &scope)?.one()?;
                            if !t.is_file_backed() {
                                bail!(
                                    "in {}: @filename on non-file-backed {}",
                                    proc.name,
                                    t.name()
                                );
                            }
                        }
                        AppArg::Filenames(e) => {
                            let t = self.check_expr(e, &scope)?.one()?;
                            let ok = matches!(&t, Type::Array(inner)
                                if inner.is_file_backed() || matches!(**inner, Type::Struct(_)));
                            if !ok {
                                bail!(
                                    "in {}: @filenames needs an array of file-backed \
                                     datasets, got {}",
                                    proc.name,
                                    t.name()
                                );
                            }
                        }
                        AppArg::Expr(e) => {
                            let t = self.check_expr(e, &scope)?.one()?;
                            match t {
                                Type::Int
                                | Type::Float
                                | Type::String
                                | Type::Boolean
                                | Type::File(_)
                                | Type::Table => {}
                                other => bail!(
                                    "in {}: app arg of unsupported type {}",
                                    proc.name,
                                    other.name()
                                ),
                            }
                        }
                    }
                }
                Ok(())
            }
            ProcBody::Compound(stmts) => {
                scope.push();
                for s in stmts {
                    self.check_stmt(s, &mut scope)?;
                }
                scope.pop();
                Ok(())
            }
        }
    }

    fn check_stmt(&self, stmt: &Stmt, scope: &mut Scope) -> Result<()> {
        match stmt {
            Stmt::VarDecl { ty, name, mapper, init } => {
                let t = self.resolve_ref(ty)?;
                if let Some(m) = mapper {
                    for (_, e) in &m.params {
                        // Parameter values: scalars or dataset references.
                        self.check_expr(e, scope)?.one()?;
                    }
                }
                if let Some(e) = init {
                    let et = self.check_expr(e, scope)?;
                    match et {
                        ETy::One(et) => {
                            if !assignable(&t, &et) {
                                bail!(
                                    "cannot initialize {name}: {} = {}",
                                    t.name(),
                                    et.name()
                                );
                            }
                        }
                        ETy::Tuple(_) => bail!(
                            "cannot initialize {name} from a multi-output call; \
                             use tuple assignment"
                        ),
                    }
                }
                scope.declare(name, t)
            }
            Stmt::Assign { lhs, rhs } => {
                let lt = self.lvalue_type(lhs, scope)?;
                let rt = self.check_expr(rhs, scope)?.one()?;
                if !assignable(&lt, &rt) {
                    bail!(
                        "type mismatch assigning {}: {} = {}",
                        lhs.base,
                        lt.name(),
                        rt.name()
                    );
                }
                Ok(())
            }
            Stmt::TupleAssign { lhs, rhs } => {
                let rt = self.check_expr(rhs, scope)?;
                let ETy::Tuple(outs) = rt else {
                    bail!("tuple assignment requires a multi-output call");
                };
                if outs.len() != lhs.len() {
                    bail!(
                        "tuple assignment arity mismatch: {} targets, {} outputs",
                        lhs.len(),
                        outs.len()
                    );
                }
                for (lv, ot) in lhs.iter().zip(outs) {
                    let lt = self.lvalue_type(lv, scope)?;
                    if !assignable(&lt, &ot) {
                        bail!(
                            "tuple assignment mismatch at {}: {} = {}",
                            lv.base,
                            lt.name(),
                            ot.name()
                        );
                    }
                }
                Ok(())
            }
            Stmt::Foreach { elem_ty, var, index, over, body } => {
                let ot = self.check_expr(over, scope)?.one()?;
                let elem = ot
                    .element()
                    .ok_or_else(|| {
                        anyhow!("foreach over non-array type {}", ot.name())
                    })?
                    .clone();
                if let Some(declared) = elem_ty {
                    let dt = self.resolve_ref(declared)?;
                    if dt != elem {
                        bail!(
                            "foreach element type {} does not match array of {}",
                            dt.name(),
                            elem.name()
                        );
                    }
                }
                scope.push();
                scope.declare(var, elem)?;
                if let Some(ix) = index {
                    scope.declare(ix, Type::Int)?;
                }
                for s in body {
                    self.check_stmt(s, scope)?;
                }
                scope.pop();
                Ok(())
            }
            Stmt::If { cond, then_body, else_body } => {
                let ct = self.check_expr(cond, scope)?.one()?;
                if ct != Type::Boolean {
                    bail!("if condition must be boolean, got {}", ct.name());
                }
                scope.push();
                for s in then_body {
                    self.check_stmt(s, scope)?;
                }
                scope.pop();
                scope.push();
                for s in else_body {
                    self.check_stmt(s, scope)?;
                }
                scope.pop();
                Ok(())
            }
        }
    }

    fn lvalue_type(&self, lv: &LValue, scope: &Scope) -> Result<Type> {
        let mut t = scope.lookup(&lv.base)?;
        for acc in &lv.path {
            t = match acc {
                Access::Member(m) => self.env.member_type(&t, m)?,
                Access::Index(e) => {
                    let it = self.check_expr(e, scope)?.one()?;
                    if it != Type::Int {
                        bail!("array index must be int, got {}", it.name());
                    }
                    t.element()
                        .ok_or_else(|| anyhow!("indexing non-array {}", t.name()))?
                        .clone()
                }
            };
        }
        Ok(t)
    }

    fn check_expr(&self, e: &Expr, scope: &Scope) -> Result<ETy> {
        Ok(match e {
            Expr::Int(_) => ETy::One(Type::Int),
            Expr::Float(_) => ETy::One(Type::Float),
            Expr::Str(_) => ETy::One(Type::String),
            Expr::Bool(_) => ETy::One(Type::Boolean),
            Expr::Path(lv) => ETy::One(self.lvalue_type(lv, scope)?),
            Expr::Call { name, args } => {
                let proc = self
                    .procs
                    .get(name)
                    .ok_or_else(|| anyhow!("unknown procedure {name}"))?;
                if args.len() != proc.inputs.len() {
                    bail!(
                        "{name} expects {} arguments, got {}",
                        proc.inputs.len(),
                        args.len()
                    );
                }
                for (a, p) in args.iter().zip(&proc.inputs) {
                    let at = self.check_expr(a, scope)?.one()?;
                    let pt = self.resolve_ref(&p.ty)?;
                    if !assignable(&pt, &at) {
                        bail!(
                            "{name}: argument {} is {}, expected {}",
                            p.name,
                            at.name(),
                            pt.name()
                        );
                    }
                }
                let outs: Vec<Type> = proc
                    .outputs
                    .iter()
                    .map(|o| self.resolve_ref(&o.ty))
                    .collect::<Result<_>>()?;
                if outs.len() == 1 {
                    ETy::One(outs.into_iter().next().unwrap())
                } else {
                    ETy::Tuple(outs)
                }
            }
            Expr::Binary { op, lhs, rhs } => {
                let lt = self.check_expr(lhs, scope)?.one()?;
                let rt = self.check_expr(rhs, scope)?.one()?;
                let numeric = |t: &Type| matches!(t, Type::Int | Type::Float);
                match op {
                    BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Div => {
                        if !numeric(&lt) || !numeric(&rt) {
                            bail!(
                                "arithmetic on non-numeric {} / {}",
                                lt.name(),
                                rt.name()
                            );
                        }
                        if lt == Type::Float || rt == Type::Float {
                            ETy::One(Type::Float)
                        } else {
                            ETy::One(Type::Int)
                        }
                    }
                    _ => {
                        let comparable = (numeric(&lt) && numeric(&rt))
                            || (lt == Type::String && rt == Type::String);
                        if !comparable {
                            bail!(
                                "cannot compare {} with {}",
                                lt.name(),
                                rt.name()
                            );
                        }
                        ETy::One(Type::Boolean)
                    }
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::swiftscript::parser::parse;

    /// Self-contained fMRI workflow (Figure 1 with all procedures
    /// declared) used across the test suite.
    pub const FMRI_FULL: &str = r#"
type Image {};
type Header {};
type Volume { Image img; Header hdr; };
type Run { Volume v[]; };
type Air {};
type AirVector { Air a[]; };

(Volume ov) reorient (Volume iv, string direction, string overwrite) {
  app { reorient @filename(iv.img) @filename(ov.img) direction overwrite; }
}
(Air out) alignlinear (Volume std, Volume iv, int m, int x, int y, string opts) {
  app { alignlinear @filename(std.img) @filename(iv.img) @filename(out) m x y opts; }
}
(Volume ov) reslice (Volume iv, Air align, string o, string k) {
  app { reslice @filename(align) @filename(iv.img) @filename(ov.img) o k; }
}
(Run or) reorientRun (Run ir, string direction, string overwrite) {
  foreach Volume iv, i in ir.v {
    or.v[i] = reorient(iv, direction, overwrite);
  }
}
(AirVector ov) alignlinearRun (Volume std, Run ir, int m, int x, int y, string opts) {
  foreach Volume iv, i in ir.v {
    ov.a[i] = alignlinear(std, iv, m, x, y, opts);
  }
}
(Run or) resliceRun (Run ir, AirVector av, string o, string k) {
  foreach Volume iv, i in ir.v {
    or.v[i] = reslice(iv, av.a[i], o, k);
  }
}
(Run resliced) fmri_wf (Run r) {
  Run yroRun = reorientRun( r, "y", "n" );
  Run roRun = reorientRun( yroRun, "x", "n" );
  Volume std = roRun.v[1];
  AirVector roAirVec = alignlinearRun(std, roRun, 12, 1000, 1000, "81 3 3");
  resliced = resliceRun( roRun, roAirVec, "-o", "-k");
}
Run bold1<run_mapper;location="fmridc/functional_data/",prefix="bold1">;
Run sbold1<run_mapper;location="fmridc/functional_data/",prefix="sbold1">;
sbold1 = fmri_wf(bold1);
"#;

    #[test]
    fn accepts_full_fmri_workflow() {
        let tp = typecheck(parse(FMRI_FULL).unwrap()).unwrap();
        assert_eq!(tp.procs.len(), 7);
        assert_eq!(
            tp.global_types.get("bold1"),
            Some(&Type::Struct("Run".into()))
        );
    }

    fn check(src: &str) -> Result<TypedProgram> {
        typecheck(parse(src).unwrap())
    }

    #[test]
    fn rejects_unknown_type() {
        assert!(check("Bogus x;").is_err());
    }

    #[test]
    fn rejects_unknown_procedure() {
        let err = check("int x = f(1);").unwrap_err().to_string();
        assert!(err.contains("unknown procedure"), "{err}");
    }

    #[test]
    fn rejects_arity_mismatch() {
        let src = r#"
type Image {};
(Image o) f (Image a, int n) { app { f @filename(a) n @filename(o); } }
Image x<file_mapper;file="x">;
Image y = f(x);
"#;
        let err = check(src).unwrap_err().to_string();
        assert!(err.contains("expects 2 arguments"), "{err}");
    }

    #[test]
    fn rejects_argument_type_mismatch() {
        let src = r#"
type Image {};
(Image o) f (int n) { app { f n @filename(o); } }
Image y = f("notanint");
"#;
        assert!(check(src).is_err());
    }

    #[test]
    fn int_coerces_to_float_argument() {
        let src = r#"
type Image {};
(Image o) f (float x) { app { f x @filename(o); } }
Image y = f(3);
"#;
        assert!(check(src).is_ok());
    }

    #[test]
    fn rejects_foreach_over_scalar() {
        let err = check("int n = 3;\nforeach v in n { int m = 1; }")
            .unwrap_err()
            .to_string();
        assert!(err.contains("foreach over non-array"), "{err}");
    }

    #[test]
    fn rejects_foreach_element_type_mismatch() {
        let src = r#"
type Image {};
type Pair { Image a; Image b; };
type Bag { Pair p[]; };
Bag bag<run_mapper;location="d",prefix="x">;
foreach Image v in bag.p { Image w = v; }
"#;
        assert!(check(src).is_err());
    }

    #[test]
    fn rejects_nonboolean_if() {
        let err = check("if (3) { int x = 1; }").unwrap_err().to_string();
        assert!(err.contains("must be boolean"), "{err}");
    }

    #[test]
    fn rejects_filename_on_scalar() {
        let src = r#"
type Image {};
(Image o) f (int n) { app { f @filename(n) @filename(o); } }
"#;
        let err = check(src).unwrap_err().to_string();
        assert!(err.contains("@filename on non-file-backed"), "{err}");
    }

    #[test]
    fn rejects_duplicate_variable() {
        assert!(check("int x = 1; int x = 2;").is_err());
    }

    #[test]
    fn rejects_procedure_without_outputs() {
        let src = "type Image {};\n() f (Image a) { app { f @filename(a); } }";
        // Parser produces empty outputs; typecheck rejects.
        assert!(check(src).is_err());
    }

    #[test]
    fn tuple_assignment_arity_checked() {
        let src = r#"
type Image {};
(Image a, Image b) f (Image x) { app { f @filename(x) @filename(a) @filename(b); } }
Image i<file_mapper;file="i">;
Image p;
Image q;
(p, q) = f(i);
"#;
        assert!(check(src).is_ok());
        let bad = r#"
type Image {};
(Image a, Image b) f (Image x) { app { f @filename(x) @filename(a) @filename(b); } }
Image i<file_mapper;file="i">;
Image p;
(p) = f(i);
"#;
        assert!(check(bad).is_err());
    }

    #[test]
    fn member_access_checked() {
        let src = r#"
type Image {};
type Volume { Image img; };
Volume v<file_mapper;file="v">;
Image i = v.img;
"#;
        assert!(check(src).is_ok());
        let bad = r#"
type Image {};
type Volume { Image img; };
Volume v<file_mapper;file="v">;
Image i = v.nope;
"#;
        assert!(check(bad).is_err());
    }

    #[test]
    fn comparison_types() {
        assert!(check(r#"int n = 3; if (n >= 2) { int y = 1; }"#).is_ok());
        assert!(check(r#"if ("a" < 3) { int y = 1; }"#).is_err());
        assert!(check(r#"if ("a" != "b") { int y = 1; }"#).is_ok());
    }

    #[test]
    fn arithmetic_result_types() {
        assert!(check("float f = 1 + 2.5;").is_ok());
        assert!(check("int i = 1 + 2.5;").is_err());
        assert!(check(r#"int i = 1 + "x";"#).is_err());
    }
}
