//! SwiftScript — the paper's workflow language (§3.1–3.7).
//!
//! A hand-written lexer + recursive-descent parser for the SwiftScript
//! subset the paper demonstrates (Figures 1 and 3), an XDTM-based type
//! checker, and the typed program representation the Karajan engine
//! interprets:
//!
//! - C-style dataset type declarations (`type Volume { Image img; ... }`)
//! - atomic procedures with `app { ... }` bodies and the `@filename`
//!   mapping builtin
//! - compound procedures (multiple outputs supported)
//! - `foreach v, i in expr { ... }` parallel iteration
//! - `if` conditional execution
//! - dataset mapping declarations
//!   (`Run bold1<run_mapper;location="...",prefix="bold1">;`)
//! - member/index paths, string/int/float literals, comparison and
//!   arithmetic operators.

pub mod ast;
pub mod lexer;
pub mod parser;
pub mod typecheck;

pub use ast::*;
pub use lexer::{Lexer, Token, TokenKind};
pub use parser::parse;
pub use typecheck::{typecheck, TypedProgram};

/// Parse + typecheck in one step.
pub fn compile(source: &str) -> anyhow::Result<TypedProgram> {
    typecheck(parse(source)?)
}
