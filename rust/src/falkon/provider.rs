//! The Falkon provider: adapts [`FalkonService`] to the Karajan
//! [`Provider`] interface (paper §5.3: "submitting jobs to the Falkon
//! service via the Falkon provider that we developed").

use std::sync::Arc;

use crate::providers::{AppTask, BundleDone, Provider, TaskDone};

use super::service::FalkonService;

/// Provider adapter over a running Falkon service.
pub struct FalkonProvider {
    name: String,
    service: Arc<FalkonService>,
}

impl FalkonProvider {
    /// Wrap a running service as a named scheduler site.
    pub fn new(name: &str, service: Arc<FalkonService>) -> Self {
        Self { name: name.to_string(), service }
    }

    /// The underlying service handle (stats, drain, TCP endpoint setup).
    pub fn service(&self) -> &Arc<FalkonService> {
        &self.service
    }
}

impl Provider for FalkonProvider {
    fn name(&self) -> &str {
        &self.name
    }

    fn submit(&self, bundle: Vec<AppTask>, done: BundleDone) {
        // Falkon's fine-grained dispatch makes clustering unnecessary
        // (paper §3.13), but the provider interface allows bundles: the
        // service enqueues the whole bundle with one batched queue
        // operation and aggregates completions in submission order.
        self.service.submit_bundle(bundle, done);
    }

    fn submit_stream(&self, batch: Vec<(AppTask, TaskDone)>) {
        // The streaming path maps 1:1 onto the service's batched submit:
        // one sharded-queue push (one lock + wakeup per shard) for the
        // whole batch, with each task carrying its own completion — this
        // is where the engine's unclustered flush lands.
        self.service.submit_batch(batch);
    }

    fn slots(&self) -> usize {
        self.service.live_executors().max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::falkon::service::{FalkonServiceConfig, RealDrpPolicy};
    use std::time::Duration;

    fn task(id: u64) -> AppTask {
        AppTask {
            id,
            key: format!("k{id}"),
            executable: "x".into(),
            args: vec![],
            inputs: vec![],
            outputs: vec![],
        }
    }

    #[test]
    fn bundles_aggregate_in_order() {
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(4),
                executor_overhead: Duration::ZERO,
            },
            Arc::new(|_t| Ok(())),
        );
        let p = FalkonProvider::new("falkon", svc);
        let (tx, rx) = std::sync::mpsc::channel();
        p.submit(
            (0..8).map(task).collect(),
            Box::new(move |rs| tx.send(rs).unwrap()),
        );
        let rs = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(rs.len(), 8);
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(r.id, i as u64, "results keep bundle order");
            assert!(r.ok);
        }
    }

    #[test]
    fn stream_completions_are_not_delayed_by_batch_peers() {
        // Two executors; task 0 blocks until task 1's completion has
        // been observed. If submit_stream delayed completions until the
        // whole batch finished (bundle semantics), this would deadlock
        // and the recv below would time out.
        let (unblock_tx, unblock_rx) = std::sync::mpsc::channel::<()>();
        let unblock_rx = std::sync::Mutex::new(unblock_rx);
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(2),
                executor_overhead: Duration::ZERO,
            },
            Arc::new(move |t: &AppTask| {
                if t.id == 0 {
                    unblock_rx
                        .lock()
                        .unwrap()
                        .recv_timeout(Duration::from_secs(10))
                        .map_err(|_| anyhow::anyhow!("never unblocked"))?;
                }
                Ok(())
            }),
        );
        let p = FalkonProvider::new("falkon", svc);
        let (tx, rx) = std::sync::mpsc::channel();
        let batch: Vec<(AppTask, crate::providers::TaskDone)> = (0..2u64)
            .map(|i| {
                let tx = tx.clone();
                let done: crate::providers::TaskDone =
                    Box::new(move |r| tx.send(r).unwrap());
                (task(i), done)
            })
            .collect();
        p.submit_stream(batch);
        // Task 1's completion must arrive while task 0 is still running.
        let first = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(first.id, 1, "fast task completes independently");
        assert!(first.ok);
        unblock_tx.send(()).unwrap();
        let second = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(second.id, 0);
        assert!(second.ok);
    }

    #[test]
    fn empty_bundle_completes_immediately() {
        let svc = FalkonService::start(
            FalkonServiceConfig::default(),
            Arc::new(|_t| Ok(())),
        );
        let p = FalkonProvider::new("falkon", svc);
        let (tx, rx) = std::sync::mpsc::channel();
        p.submit(vec![], Box::new(move |rs| tx.send(rs).unwrap()));
        assert!(rx.recv_timeout(Duration::from_secs(1)).unwrap().is_empty());
    }
}
