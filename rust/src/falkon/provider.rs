//! The Falkon provider: adapts [`FalkonService`] to the Karajan
//! [`Provider`] interface (paper §5.3: "submitting jobs to the Falkon
//! service via the Falkon provider that we developed").

use std::sync::Arc;

use crate::providers::{AppTask, BundleDone, Provider};

use super::service::FalkonService;

/// Provider adapter over a running Falkon service.
pub struct FalkonProvider {
    name: String,
    service: Arc<FalkonService>,
}

impl FalkonProvider {
    pub fn new(name: &str, service: Arc<FalkonService>) -> Self {
        Self { name: name.to_string(), service }
    }

    pub fn service(&self) -> &Arc<FalkonService> {
        &self.service
    }
}

impl Provider for FalkonProvider {
    fn name(&self) -> &str {
        &self.name
    }

    fn submit(&self, bundle: Vec<AppTask>, done: BundleDone) {
        // Falkon's fine-grained dispatch makes clustering unnecessary
        // (paper §3.13), but the provider interface allows bundles: the
        // service enqueues the whole bundle with one batched queue
        // operation and aggregates completions in submission order.
        self.service.submit_bundle(bundle, done);
    }

    fn slots(&self) -> usize {
        self.service.live_executors().max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::falkon::service::{FalkonServiceConfig, RealDrpPolicy};
    use std::time::Duration;

    fn task(id: u64) -> AppTask {
        AppTask {
            id,
            key: format!("k{id}"),
            executable: "x".into(),
            args: vec![],
            inputs: vec![],
            outputs: vec![],
        }
    }

    #[test]
    fn bundles_aggregate_in_order() {
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(4),
                executor_overhead: Duration::ZERO,
            },
            Arc::new(|_t| Ok(())),
        );
        let p = FalkonProvider::new("falkon", svc);
        let (tx, rx) = std::sync::mpsc::channel();
        p.submit(
            (0..8).map(task).collect(),
            Box::new(move |rs| tx.send(rs).unwrap()),
        );
        let rs = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(rs.len(), 8);
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(r.id, i as u64, "results keep bundle order");
            assert!(r.ok);
        }
    }

    #[test]
    fn empty_bundle_completes_immediately() {
        let svc = FalkonService::start(
            FalkonServiceConfig::default(),
            Arc::new(|_t| Ok(())),
        );
        let p = FalkonProvider::new("falkon", svc);
        let (tx, rx) = std::sync::mpsc::channel();
        p.submit(vec![], Box::new(move |rs| tx.send(rs).unwrap()));
        assert!(rx.recv_timeout(Duration::from_secs(1)).unwrap().is_empty());
    }
}
