//! Falkon network endpoint: the client-facing interface (the paper's
//! Web-Services interface analogue) as a line-oriented TCP protocol.
//!
//! Protocol (one request per line, UTF-8):
//!
//! ```text
//! C->S:  SUBMIT <id> <executable> [args...]
//! S->C:  RESULT <id> <ok|err> <exec_us> <wait_us> [error...]
//! C->S:  STATS
//! S->C:  STATS <submitted> <completed> <failed> <queue> <executors>
//! C->S:  QUIT
//! ```
//!
//! Executors remain in-process (this testbed is one host); the endpoint
//! exists so remote clients — and the fig12 "submit from a different
//! host" benchmark — exercise a real network hop on the submit path.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::providers::AppTask;

use super::service::FalkonService;

/// TCP front-end for a Falkon service.
pub struct FalkonTcpServer {
    addr: std::net::SocketAddr,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    shutdown: Arc<std::sync::atomic::AtomicBool>,
}

impl FalkonTcpServer {
    /// Bind and serve (background threads). Use port 0 for ephemeral.
    pub fn start(service: Arc<FalkonService>, bind: &str) -> Result<Self> {
        let listener = TcpListener::bind(bind).context("bind falkon endpoint")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let sd = Arc::clone(&shutdown);
        let accept_thread = std::thread::Builder::new()
            .name("falkon-accept".into())
            .spawn(move || {
                loop {
                    if sd.load(Ordering::SeqCst) {
                        return;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let svc = Arc::clone(&service);
                            std::thread::spawn(move || {
                                let _ = serve_conn(stream, svc);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => return,
                    }
                }
            })?;
        Ok(Self { addr, accept_thread: Some(accept_thread), shutdown })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }
}

impl Drop for FalkonTcpServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn serve_conn(stream: TcpStream, svc: Arc<FalkonService>) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let writer = Arc::new(std::sync::Mutex::new(stream));
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // peer closed
        }
        let parts: Vec<&str> = line.trim().split(' ').collect();
        match parts.first().copied() {
            Some("SUBMIT") if parts.len() >= 3 => {
                let id: u64 = parts[1].parse().context("bad id")?;
                let executable = parts[2].to_string();
                let args: Vec<String> =
                    parts[3..].iter().map(|s| s.to_string()).collect();
                let task = AppTask {
                    id,
                    key: format!("tcp/{peer:?}/{id}"),
                    executable,
                    args,
                    inputs: vec![],
                    outputs: vec![],
                };
                let w = Arc::clone(&writer);
                svc.submit(
                    task,
                    Box::new(move |r| {
                        let status = if r.ok { "ok" } else { "err" };
                        let err = r.error.unwrap_or_default().replace('\n', " ");
                        let msg = format!(
                            "RESULT {} {} {} {} {}\n",
                            r.id, status, r.exec_us, r.wait_us, err
                        );
                        if let Ok(mut s) = w.lock() {
                            let _ = s.write_all(msg.as_bytes());
                        }
                    }),
                );
            }
            Some("STATS") => {
                let st = svc.stats();
                let msg = format!(
                    "STATS {} {} {} {} {}\n",
                    st.submitted.load(Ordering::SeqCst),
                    st.completed.load(Ordering::SeqCst),
                    st.failed.load(Ordering::SeqCst),
                    svc.queue_len(),
                    svc.live_executors(),
                );
                writer.lock().unwrap().write_all(msg.as_bytes())?;
            }
            Some("QUIT") => return Ok(()),
            other => bail!("bad request {other:?}"),
        }
    }
}

/// A blocking TCP client for the Falkon endpoint.
pub struct FalkonClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// One result line from the service.
#[derive(Debug, Clone, PartialEq)]
pub struct RemoteResult {
    pub id: u64,
    pub ok: bool,
    pub exec_us: u64,
    pub wait_us: u64,
    pub error: String,
}

impl FalkonClient {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect falkon")?;
        stream.set_nodelay(true).ok();
        Ok(Self { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Fire a submission without waiting.
    pub fn submit(&mut self, id: u64, executable: &str, args: &[&str]) -> Result<()> {
        let mut line = format!("SUBMIT {id} {executable}");
        for a in args {
            line.push(' ');
            line.push_str(a);
        }
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        Ok(())
    }

    /// Read the next RESULT line (results may arrive out of order).
    pub fn next_result(&mut self) -> Result<RemoteResult> {
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                bail!("connection closed");
            }
            let parts: Vec<&str> = line.trim().splitn(6, ' ').collect();
            if parts.first() == Some(&"RESULT") && parts.len() >= 5 {
                return Ok(RemoteResult {
                    id: parts[1].parse()?,
                    ok: parts[2] == "ok",
                    exec_us: parts[3].parse()?,
                    wait_us: parts[4].parse()?,
                    error: parts.get(5).unwrap_or(&"").to_string(),
                });
            }
        }
    }

    /// Convenience: submit and wait for that id.
    pub fn run(&mut self, id: u64, executable: &str, args: &[&str]) -> Result<RemoteResult> {
        self.submit(id, executable, args)?;
        loop {
            let r = self.next_result()?;
            if r.id == id {
                return Ok(r);
            }
        }
    }

    /// Query service stats.
    pub fn stats(&mut self) -> Result<(u64, u64, u64, usize, usize)> {
        self.writer.write_all(b"STATS\n")?;
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                bail!("connection closed");
            }
            let parts: Vec<&str> = line.trim().split(' ').collect();
            if parts.first() == Some(&"STATS") && parts.len() == 6 {
                return Ok((
                    parts[1].parse()?,
                    parts[2].parse()?,
                    parts[3].parse()?,
                    parts[4].parse()?,
                    parts[5].parse()?,
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::falkon::service::{FalkonServiceConfig, RealDrpPolicy};
    use std::time::Duration;

    fn start_svc() -> (Arc<FalkonService>, FalkonTcpServer) {
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(2),
                executor_overhead: Duration::ZERO,
            },
            Arc::new(|t| {
                if t.executable == "fail" {
                    anyhow::bail!("requested failure")
                }
                Ok(())
            }),
        );
        let server = FalkonTcpServer::start(Arc::clone(&svc), "127.0.0.1:0").unwrap();
        (svc, server)
    }

    #[test]
    fn tcp_submit_roundtrip() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        let r = client.run(1, "sleep0", &[]).unwrap();
        assert!(r.ok);
        assert_eq!(r.id, 1);
    }

    #[test]
    fn tcp_reports_failures() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        let r = client.run(2, "fail", &[]).unwrap();
        assert!(!r.ok);
        assert!(r.error.contains("requested failure"));
    }

    #[test]
    fn tcp_pipeline_many_submissions() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        let n = 200;
        for i in 0..n {
            client.submit(i, "sleep0", &[]).unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..n {
            let r = client.next_result().unwrap();
            assert!(r.ok);
            seen.insert(r.id);
        }
        assert_eq!(seen.len(), n as usize);
    }

    #[test]
    fn tcp_stats_query() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        client.run(1, "sleep0", &[]).unwrap();
        let (submitted, completed, failed, _q, execs) = client.stats().unwrap();
        assert_eq!(submitted, 1);
        assert_eq!(completed, 1);
        assert_eq!(failed, 0);
        assert_eq!(execs, 2);
    }
}
