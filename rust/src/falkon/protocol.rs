//! Falkon network endpoint: the client-facing interface (the paper's
//! Web-Services interface analogue) as a TCP protocol with batched,
//! count-prefixed frames.
//!
//! Frame grammar (UTF-8 lines; `<n>` is a decimal count prefixing the
//! frame body — see DESIGN.md §4.1 for ordering/ack guarantees):
//!
//! ```text
//! C->S:  SUBMIT <id> <executable> [args...]          single-task (legacy)
//! C->S:  SUBMITB <n>                                 batched submit frame
//!        <id> <executable> [args...]                 x n task lines
//! S->C:  RESULT <id> <ok|err> <exec_us> <wait_us> [error...]
//! S->C:  DONEB <n>                                   batched ack frame
//!        <id> <ok|err> <exec_us> <wait_us> [error...]   x n status lines
//! C->S:  STATS
//! S->C:  STATS <submitted> <completed> <failed> <queue> <executors>
//! C->S:  QUIT
//! ```
//!
//! A `SUBMITB` frame enters the service through one
//! [`FalkonService::submit_batch`] call (one sharded-queue push for the
//! whole frame) instead of one queue operation per line. Completions are
//! still per-task; the server coalesces whatever acks are ready at write
//! time into one `DONEB` frame (opportunistic batching — no completion
//! waits for its frame peers). Single-line `SUBMIT` requests keep their
//! legacy `RESULT`-line acks so old clients work unchanged.
//!
//! Frame *cut-off* decisions — when a stream of singles becomes a frame
//! — are the policy core's [`crate::policy::FrameCoalescer`]: the
//! server's ack path runs it with a zero age threshold (flush
//! combining, frames capped at [`MAX_FRAME_TASKS`]), and the client's
//! optional [`FalkonClient::with_autobatch`] buffer runs it with a real
//! batch/age window (the Nagle-style submit side).
//!
//! Executors remain in-process (this testbed is one host); the endpoint
//! exists so remote clients — and the fig12 "submit from a different
//! host" benchmark — exercise a real network hop on the submit path.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::policy::{FrameCoalescer, FramePolicy, RealClock};
use crate::providers::{AppTask, TaskDone};

use super::service::FalkonService;

/// Upper bound on `<n>` in a `SUBMITB`/`DONEB` header: a defense against
/// absurd counts from malformed or hostile peers (the paper's service
/// queues 1.5M tasks total; no single frame needs more than this).
pub const MAX_FRAME_TASKS: usize = 65_536;

/// One task as it crosses the wire (the client-side mirror of the
/// `SUBMITB` task line `<id> <executable> [args...]`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskSpec {
    /// Client-chosen task id, echoed back in the ack.
    pub id: u64,
    /// Logical executable name (resolved by the server's app registry).
    pub executable: String,
    /// Command-line words after the executable (no embedded whitespace).
    pub args: Vec<String>,
}

/// One result line from the service (a `RESULT` line or one `DONEB`
/// status line — both carry the same fields).
#[derive(Debug, Clone, PartialEq)]
pub struct RemoteResult {
    /// The id the task was submitted with.
    pub id: u64,
    /// True when the task ran to success.
    pub ok: bool,
    /// Executor-side execution time in microseconds.
    pub exec_us: u64,
    /// Service-queue wait time in microseconds.
    pub wait_us: u64,
    /// Error message for failed tasks (newlines flattened; empty on ok).
    pub error: String,
}

// ---------------------------------------------------------------------
// Frame encode/decode (pure; unit-testable without sockets)
// ---------------------------------------------------------------------

/// Encode a `SUBMITB` frame: the `SUBMITB <n>` header line followed by
/// `n` task lines. Fails if an executable or arg contains whitespace —
/// an embedded space would silently split into extra wire args, and an
/// embedded newline would desynchronize the frame (the receiver counts
/// lines), so both are rejected before anything touches the wire.
pub fn encode_submitb(tasks: &[TaskSpec]) -> Result<String> {
    let mut out = format!("SUBMITB {}\n", tasks.len());
    for t in tasks {
        ensure_wire_word(&t.executable, "executable")?;
        out.push_str(&t.id.to_string());
        out.push(' ');
        out.push_str(&t.executable);
        for a in &t.args {
            ensure_wire_word(a, "arg")?;
            out.push(' ');
            out.push_str(a);
        }
        out.push('\n');
    }
    Ok(out)
}

/// A wire word is one non-empty token of a task line: no whitespace.
fn ensure_wire_word(s: &str, what: &str) -> Result<()> {
    if s.is_empty() || s.contains(char::is_whitespace) {
        bail!("task {what} {s:?} must be non-empty and whitespace-free");
    }
    Ok(())
}

/// Decode the body of a `SUBMITB` frame — the `n` task lines following
/// an already-consumed header. Fails on a count above
/// [`MAX_FRAME_TASKS`], on EOF before `n` lines arrive (truncated
/// frame), and on malformed task lines.
pub fn decode_submitb_body(n: usize, reader: &mut impl BufRead) -> Result<Vec<TaskSpec>> {
    if n > MAX_FRAME_TASKS {
        bail!("SUBMITB frame of {n} tasks exceeds the {MAX_FRAME_TASKS} cap");
    }
    let mut tasks = Vec::with_capacity(n);
    let mut line = String::new();
    for i in 0..n {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            bail!("truncated SUBMITB frame: got {i} of {n} task lines");
        }
        let mut parts = line.trim().split(' ').filter(|s| !s.is_empty());
        let id: u64 = parts
            .next()
            .context("SUBMITB task line missing id")?
            .parse()
            .context("SUBMITB task line: bad id")?;
        let executable = parts
            .next()
            .context("SUBMITB task line missing executable")?
            .to_string();
        let args = parts.map(|s| s.to_string()).collect();
        tasks.push(TaskSpec { id, executable, args });
    }
    Ok(tasks)
}

/// Render one status line (shared by `RESULT` acks, which prefix it with
/// the keyword, and `DONEB` body lines).
fn status_line(r: &RemoteResult) -> String {
    let status = if r.ok { "ok" } else { "err" };
    let err = r.error.replace('\n', " ");
    format!("{} {} {} {} {}\n", r.id, status, r.exec_us, r.wait_us, err)
}

/// Encode a `DONEB` frame: the `DONEB <n>` header line followed by `n`
/// status lines.
pub fn encode_doneb(results: &[RemoteResult]) -> String {
    let mut out = format!("DONEB {}\n", results.len());
    for r in results {
        out.push_str(&status_line(r));
    }
    out
}

/// Parse the fields of one status line (after any keyword prefix has
/// been stripped): `<id> <ok|err> <exec_us> <wait_us> [error...]`.
fn parse_status_fields(fields: &str) -> Result<RemoteResult> {
    let parts: Vec<&str> = fields.trim().splitn(5, ' ').collect();
    if parts.len() < 4 {
        bail!("malformed status line: {fields:?}");
    }
    Ok(RemoteResult {
        id: parts[0].parse().context("status line: bad id")?,
        ok: parts[1] == "ok",
        exec_us: parts[2].parse().context("status line: bad exec_us")?,
        wait_us: parts[3].parse().context("status line: bad wait_us")?,
        error: parts.get(4).map(|s| s.trim_end()).unwrap_or("").to_string(),
    })
}

/// Decode the body of a `DONEB` frame — the `n` status lines following
/// an already-consumed header. Fails on an oversized count and on EOF
/// before `n` lines arrive (truncated frame).
pub fn decode_doneb_body(n: usize, reader: &mut impl BufRead) -> Result<Vec<RemoteResult>> {
    if n > MAX_FRAME_TASKS {
        bail!("DONEB frame of {n} results exceeds the {MAX_FRAME_TASKS} cap");
    }
    let mut results = Vec::with_capacity(n);
    let mut line = String::new();
    for i in 0..n {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            bail!("truncated DONEB frame: got {i} of {n} status lines");
        }
        results.push(parse_status_fields(&line)?);
    }
    Ok(results)
}

// ---------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------

/// TCP front-end for a Falkon service.
pub struct FalkonTcpServer {
    addr: std::net::SocketAddr,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    shutdown: Arc<std::sync::atomic::AtomicBool>,
}

impl FalkonTcpServer {
    /// Bind and serve (background threads). Use port 0 for ephemeral.
    pub fn start(service: Arc<FalkonService>, bind: &str) -> Result<Self> {
        let listener = TcpListener::bind(bind).context("bind falkon endpoint")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let sd = Arc::clone(&shutdown);
        let accept_thread = std::thread::Builder::new()
            .name("falkon-accept".into())
            .spawn(move || {
                loop {
                    if sd.load(Ordering::SeqCst) {
                        return;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let svc = Arc::clone(&service);
                            std::thread::spawn(move || {
                                let _ = serve_conn(stream, svc);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => return,
                    }
                }
            })?;
        Ok(Self { addr, accept_thread: Some(accept_thread), shutdown })
    }

    /// The bound address (useful with ephemeral port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }
}

impl Drop for FalkonTcpServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

/// Per-connection shared state: the write half plus the pending-ack
/// coalescer that cuts completions into `DONEB` frames.
///
/// The cut-off rule is the policy core's [`FrameCoalescer`] with a zero
/// age threshold: an ack never *waits* for peers — every completion
/// triggers a flush — but completions that accumulate while another
/// completion holds the write lock coalesce into one frame (flush
/// combining). The coalescer's batch cap also guarantees no `DONEB`
/// frame ever exceeds [`MAX_FRAME_TASKS`], which an unbounded ack
/// buffer could previously overflow under extreme backlog.
struct ConnState {
    writer: Mutex<TcpStream>,
    acks: Mutex<FrameCoalescer<RealClock, RemoteResult>>,
}

impl ConnState {
    /// Queue one completion and flush whatever frames are due.
    fn push_ack(&self, r: RemoteResult) {
        let full = self.acks.lock().unwrap().push(r, Instant::now());
        if let Some(frame) = full {
            self.write_doneb(&frame);
        }
        self.flush_acks();
    }

    fn flush_acks(&self) {
        loop {
            let batch = self.acks.lock().unwrap().take_due(Instant::now());
            let Some(batch) = batch else { return };
            self.write_doneb(&batch);
            // Loop: completions that arrived during the write get their
            // own frame now instead of waiting for the next completion.
        }
    }

    fn write_doneb(&self, batch: &[RemoteResult]) {
        let frame = encode_doneb(batch);
        if let Ok(mut w) = self.writer.lock() {
            let _ = w.write_all(frame.as_bytes());
        }
    }
}

fn serve_conn(stream: TcpStream, svc: Arc<FalkonService>) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let conn = Arc::new(ConnState {
        writer: Mutex::new(stream),
        acks: Mutex::new(FrameCoalescer::new(FramePolicy {
            max_tasks: MAX_FRAME_TASKS,
            max_age: Duration::ZERO,
        })),
    });
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // peer closed
        }
        let parts: Vec<&str> = line.trim().split(' ').collect();
        match parts.first().copied() {
            Some("SUBMIT") if parts.len() >= 3 => {
                let id: u64 = parts[1].parse().context("bad id")?;
                let executable = parts[2].to_string();
                let args: Vec<String> =
                    parts[3..].iter().map(|s| s.to_string()).collect();
                let task = app_task(TaskSpec { id, executable, args }, &peer);
                let c = Arc::clone(&conn);
                svc.submit(
                    task,
                    Box::new(move |r| {
                        // Legacy single-task ack: one RESULT line.
                        let msg = format!("RESULT {}", status_line(&remote(r)));
                        if let Ok(mut s) = c.writer.lock() {
                            let _ = s.write_all(msg.as_bytes());
                        }
                    }),
                );
            }
            Some("SUBMITB") if parts.len() == 2 => {
                let n: usize = parts[1].parse().context("bad SUBMITB count")?;
                let specs = decode_submitb_body(n, &mut reader)?;
                // One service call for the whole frame: the batched
                // queue push amortizes locks/wakeups across the frame.
                let batch: Vec<(AppTask, TaskDone)> = specs
                    .into_iter()
                    .map(|spec| {
                        let task = app_task(spec, &peer);
                        let c = Arc::clone(&conn);
                        let done: TaskDone =
                            Box::new(move |r| c.push_ack(remote(r)));
                        (task, done)
                    })
                    .collect();
                svc.submit_batch(batch);
            }
            Some("STATS") => {
                let st = svc.stats();
                let msg = format!(
                    "STATS {} {} {} {} {}\n",
                    st.submitted.load(Ordering::SeqCst),
                    st.completed.load(Ordering::SeqCst),
                    st.failed.load(Ordering::SeqCst),
                    svc.queue_len(),
                    svc.live_executors(),
                );
                conn.writer.lock().unwrap().write_all(msg.as_bytes())?;
            }
            Some("QUIT") => return Ok(()),
            other => bail!("bad request {other:?}"),
        }
    }
}

/// Build the server-side [`AppTask`] for a wire task.
fn app_task(spec: TaskSpec, peer: &Option<std::net::SocketAddr>) -> AppTask {
    AppTask {
        id: spec.id,
        key: format!("tcp/{peer:?}/{}", spec.id),
        executable: spec.executable,
        args: spec.args,
        inputs: vec![],
        outputs: vec![],
    }
}

/// Convert a service [`crate::providers::TaskResult`] to its wire form.
fn remote(r: crate::providers::TaskResult) -> RemoteResult {
    RemoteResult {
        id: r.id,
        ok: r.ok,
        exec_us: r.exec_us,
        wait_us: r.wait_us,
        error: r.error.unwrap_or_default(),
    }
}

// ---------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------

/// Shared autobatch state: the submit coalescer plus the condvar the
/// optional timer thread sleeps on.
struct SubmitBuf {
    buf: Mutex<FrameCoalescer<RealClock, TaskSpec>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// A blocking TCP client for the Falkon endpoint. Decodes both legacy
/// `RESULT` lines and batched `DONEB` frames into a single result
/// stream.
///
/// With [`FalkonClient::with_autobatch`], a stream of single
/// [`FalkonClient::submit_buffered`] calls is Nagle-style coalesced
/// into `SUBMITB` frames by the policy core's [`FrameCoalescer`]: a
/// frame ships when the batch cap fills or the oldest buffered task
/// crosses the age threshold (checked on every client call), and
/// [`FalkonClient::flush`] is the escape hatch. Reading results
/// auto-flushes first, so a buffered submit can never deadlock against
/// its own ack. [`FalkonClient::with_autobatch_timer`] additionally
/// spawns a timer thread so age-based flushes fire even when the
/// caller makes no further client calls; dropping the client shuts the
/// thread down and joins it.
pub struct FalkonClient {
    reader: BufReader<TcpStream>,
    /// Write half, lockable so the autobatch timer thread can ship
    /// frames concurrently with caller writes (frames never
    /// interleave mid-write).
    writer: Arc<Mutex<TcpStream>>,
    /// Results decoded from a `DONEB` frame (or stashed while waiting
    /// for a STATS reply) but not yet handed to the caller.
    pending: VecDeque<RemoteResult>,
    /// Nagle-style submit buffer (None until `with_autobatch`).
    submit_buf: Option<Arc<SubmitBuf>>,
    /// Age-flush timer thread (None until `with_autobatch_timer`).
    timer: Option<std::thread::JoinHandle<()>>,
}

impl FalkonClient {
    /// Connect to a running [`FalkonTcpServer`].
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect falkon")?;
        stream.set_nodelay(true).ok();
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: Arc::new(Mutex::new(stream)),
            pending: VecDeque::new(),
            submit_buf: None,
            timer: None,
        })
    }

    /// Enable Nagle-style submit coalescing: buffered submissions cut
    /// into `SUBMITB` frames of up to `max_tasks` (clamped to the wire
    /// cap), or whenever the oldest buffered task is `max_age` old
    /// (checked on every client call; see
    /// [`FalkonClient::with_autobatch_timer`] for call-free flushes).
    pub fn with_autobatch(mut self, max_tasks: usize, max_age: Duration) -> Self {
        self.submit_buf = Some(Arc::new(SubmitBuf {
            buf: Mutex::new(FrameCoalescer::new(FramePolicy {
                max_tasks: max_tasks.clamp(1, MAX_FRAME_TASKS),
                max_age,
            })),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        }));
        self
    }

    /// [`FalkonClient::with_autobatch`] plus a timer thread: the age
    /// cut-off fires on the coalescer's own deadline, so a buffered
    /// task never waits on another client call to ship. The thread
    /// joins cleanly when the client drops.
    pub fn with_autobatch_timer(self, max_tasks: usize, max_age: Duration) -> Self {
        let mut client = self.with_autobatch(max_tasks, max_age);
        let shared = Arc::clone(client.submit_buf.as_ref().expect("just set"));
        let writer = Arc::clone(&client.writer);
        let h = std::thread::Builder::new()
            .name("falkon-client-autobatch".into())
            .spawn(move || autobatch_timer_loop(shared, writer))
            .expect("spawn autobatch timer");
        client.timer = Some(h);
        client
    }

    /// Buffer one submission behind the autobatch cut-off. Without
    /// [`FalkonClient::with_autobatch`], degrades to an immediate
    /// single-task frame. Malformed specs (whitespace in a wire word)
    /// are rejected *here*, before buffering — a bad task must fail
    /// its own submit call, not poison a whole frame at cut time
    /// (where the timer thread has no caller to report to).
    pub fn submit_buffered(&mut self, spec: TaskSpec) -> Result<()> {
        ensure_wire_word(&spec.executable, "executable")?;
        for a in &spec.args {
            ensure_wire_word(a, "arg")?;
        }
        let Some(shared) = self.submit_buf.as_ref() else {
            let frame = [spec];
            return self.write_submitb(&frame);
        };
        let now = Instant::now();
        let (frame, due) = {
            let mut buf = shared.buf.lock().unwrap();
            let frame = buf.push(spec, now);
            (frame, buf.due(now))
        };
        // Wake the timer thread so it re-arms on the new deadline.
        shared.cv.notify_one();
        if let Some(frame) = frame {
            return self.write_submitb(&frame);
        }
        if due {
            return self.flush();
        }
        Ok(())
    }

    /// Ship every buffered submission now (the escape hatch; also runs
    /// before any blocking read).
    pub fn flush(&mut self) -> Result<()> {
        let Some(shared) = self.submit_buf.as_ref() else {
            return Ok(());
        };
        loop {
            let frame = shared.buf.lock().unwrap().take_frame();
            match frame {
                Some(frame) => self.write_submitb(&frame)?,
                None => return Ok(()),
            }
        }
    }

    fn write_submitb(&self, frame: &[TaskSpec]) -> Result<()> {
        let wire = encode_submitb(frame)?;
        self.writer.lock().unwrap().write_all(wire.as_bytes())?;
        Ok(())
    }

    /// Fire a single submission (legacy line) without waiting.
    pub fn submit(&mut self, id: u64, executable: &str, args: &[&str]) -> Result<()> {
        let mut line = format!("SUBMIT {id} {executable}");
        for a in args {
            line.push(' ');
            line.push_str(a);
        }
        line.push('\n');
        self.writer.lock().unwrap().write_all(line.as_bytes())?;
        Ok(())
    }

    /// Fire a whole batch as `SUBMITB` frames (one write and one
    /// server-side queue operation per frame) without waiting. Batches
    /// above [`MAX_FRAME_TASKS`] are split into maximal frames so no
    /// legal call can trip the server's frame cap.
    pub fn submit_batch(&mut self, tasks: &[TaskSpec]) -> Result<()> {
        for frame in tasks.chunks(MAX_FRAME_TASKS) {
            self.write_submitb(frame)?;
        }
        Ok(())
    }

    /// Read the next completion (results may arrive in any order, from
    /// `RESULT` lines or `DONEB` frames alike). Flushes any buffered
    /// submissions first so the read can't deadlock on them.
    pub fn next_result(&mut self) -> Result<RemoteResult> {
        if let Some(r) = self.pending.pop_front() {
            return Ok(r);
        }
        self.flush()?;
        // One reused line buffer: this is the ack hot path (fig12 reads
        // tens of thousands of lines per run).
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                bail!("connection closed");
            }
            self.decode_ack_line(&line)?;
            if let Some(r) = self.pending.pop_front() {
                return Ok(r);
            }
        }
    }

    /// Decode one server line that may carry results (`RESULT` or a
    /// `DONEB` header) into `pending`; other lines are ignored.
    fn decode_ack_line(&mut self, line: &str) -> Result<()> {
        let trimmed = line.trim();
        if let Some(fields) = trimmed.strip_prefix("RESULT ") {
            self.pending.push_back(parse_status_fields(fields)?);
        } else if let Some(count) = trimmed.strip_prefix("DONEB ") {
            let n: usize = count.trim().parse().context("bad DONEB count")?;
            self.pending.extend(decode_doneb_body(n, &mut self.reader)?);
        }
        Ok(())
    }

    /// Convenience: submit one task and wait for that id.
    pub fn run(&mut self, id: u64, executable: &str, args: &[&str]) -> Result<RemoteResult> {
        self.submit(id, executable, args)?;
        loop {
            let r = self.next_result()?;
            if r.id == id {
                return Ok(r);
            }
        }
    }

    /// Query service stats: (submitted, completed, failed, queue length,
    /// live executors). Results arriving before the STATS reply are
    /// stashed for later [`FalkonClient::next_result`] calls, not
    /// dropped.
    pub fn stats(&mut self) -> Result<(u64, u64, u64, usize, usize)> {
        self.flush()?;
        self.writer.lock().unwrap().write_all(b"STATS\n")?;
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                bail!("connection closed");
            }
            let parts: Vec<&str> = line.trim().split(' ').collect();
            if parts.first() == Some(&"STATS") && parts.len() == 6 {
                return Ok((
                    parts[1].parse()?,
                    parts[2].parse()?,
                    parts[3].parse()?,
                    parts[4].parse()?,
                    parts[5].parse()?,
                ));
            }
            self.decode_ack_line(&line)?;
        }
    }
}

impl Drop for FalkonClient {
    fn drop(&mut self) {
        if let Some(shared) = self.submit_buf.as_ref() {
            // Store the flag while holding the buffer lock so the
            // timer thread is either before its shutdown check (and
            // will see the flag) or parked in the condvar (and gets
            // the notification) — no missed-wakeup window.
            let _guard = shared
                .buf
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.cv.notify_all();
        }
        if let Some(h) = self.timer.take() {
            let _ = h.join();
        }
    }
}

/// The autobatch timer thread: sleep until the coalescer's age
/// deadline, cut and ship the due frame, repeat. Mirrors the
/// scheduler's clustering flusher — the coalescer owns the cut-off,
/// this thread owns only the waiting.
///
/// Error semantics match the server's ack writer: a failed socket
/// write drops the frame silently and the caller discovers the broken
/// connection on its next read (specs are validated before buffering,
/// so encode itself cannot fail here). Writes are blocking — like
/// every TCP write in this endpoint — so a peer that stops reading
/// mid-frame can stall the timer (and a concurrent `drop` of the
/// client, which joins this thread) until the kernel buffer drains or
/// the connection dies.
fn autobatch_timer_loop(shared: Arc<SubmitBuf>, writer: Arc<Mutex<TcpStream>>) {
    let mut buf = shared.buf.lock().unwrap_or_else(|e| e.into_inner());
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match buf.deadline() {
            None => {
                buf = shared.cv.wait(buf).unwrap_or_else(|e| e.into_inner());
            }
            Some(deadline) => {
                let now = Instant::now();
                if now >= deadline {
                    let frame = buf.take_frame();
                    drop(buf);
                    if let Some(frame) = frame {
                        if let Ok(wire) = encode_submitb(&frame) {
                            if let Ok(mut w) = writer.lock() {
                                let _ = w.write_all(wire.as_bytes());
                            }
                        }
                    }
                    buf = shared.buf.lock().unwrap_or_else(|e| e.into_inner());
                } else {
                    let (g, _) = shared
                        .cv
                        .wait_timeout(buf, deadline.saturating_duration_since(now))
                        .unwrap_or_else(|e| e.into_inner());
                    buf = g;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::falkon::service::{FalkonServiceConfig, RealDrpPolicy};
    use std::io::Cursor;
    use std::time::Duration;

    fn start_svc() -> (Arc<FalkonService>, FalkonTcpServer) {
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(2),
                executor_overhead: Duration::ZERO,
            },
            Arc::new(|t| {
                if t.executable == "fail" {
                    anyhow::bail!("requested failure")
                }
                Ok(())
            }),
        );
        let server = FalkonTcpServer::start(Arc::clone(&svc), "127.0.0.1:0").unwrap();
        (svc, server)
    }

    fn spec(id: u64, exe: &str, args: &[&str]) -> TaskSpec {
        TaskSpec {
            id,
            executable: exe.into(),
            args: args.iter().map(|s| s.to_string()).collect(),
        }
    }

    // -- pure frame round-trips ----------------------------------------

    #[test]
    fn submitb_frame_roundtrip() {
        let tasks = vec![
            spec(1, "convert", &["-i", "a.img", "-o", "b.img"]),
            spec(2, "sleep0", &[]),
            spec(99, "align", &["m12"]),
        ];
        let wire = encode_submitb(&tasks).unwrap();
        let mut lines = wire.lines();
        let header = lines.next().unwrap();
        assert_eq!(header, "SUBMITB 3");
        let body = wire.splitn(2, '\n').nth(1).unwrap();
        let decoded = decode_submitb_body(3, &mut Cursor::new(body)).unwrap();
        assert_eq!(decoded, tasks);
    }

    #[test]
    fn doneb_frame_roundtrip() {
        let results = vec![
            RemoteResult { id: 7, ok: true, exec_us: 120, wait_us: 3, error: String::new() },
            RemoteResult {
                id: 8,
                ok: false,
                exec_us: 0,
                wait_us: 11,
                error: "boom with spaces".into(),
            },
        ];
        let wire = encode_doneb(&results);
        assert!(wire.starts_with("DONEB 2\n"));
        let body = wire.splitn(2, '\n').nth(1).unwrap();
        let decoded = decode_doneb_body(2, &mut Cursor::new(body)).unwrap();
        assert_eq!(decoded, results);
    }

    #[test]
    fn truncated_submitb_frame_is_an_error() {
        let tasks: Vec<TaskSpec> = (0..4).map(|i| spec(i, "x", &[])).collect();
        let wire = encode_submitb(&tasks).unwrap();
        let body = wire.splitn(2, '\n').nth(1).unwrap();
        // Keep only the first two task lines of four.
        let cut: String = body.lines().take(2).map(|l| format!("{l}\n")).collect();
        let err = decode_submitb_body(4, &mut Cursor::new(cut)).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn truncated_doneb_frame_is_an_error() {
        let err = decode_doneb_body(3, &mut Cursor::new("1 ok 5 5 \n")).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn oversized_frame_counts_are_rejected() {
        let e = decode_submitb_body(MAX_FRAME_TASKS + 1, &mut Cursor::new("")).unwrap_err();
        assert!(format!("{e:#}").contains("cap"), "{e:#}");
        let e = decode_doneb_body(MAX_FRAME_TASKS + 1, &mut Cursor::new("")).unwrap_err();
        assert!(format!("{e:#}").contains("cap"), "{e:#}");
    }

    #[test]
    fn malformed_task_line_is_an_error() {
        // Missing executable.
        assert!(decode_submitb_body(1, &mut Cursor::new("42\n")).is_err());
        // Non-numeric id.
        assert!(decode_submitb_body(1, &mut Cursor::new("nope x\n")).is_err());
    }

    #[test]
    fn encode_rejects_whitespace_in_wire_words() {
        // An embedded space would split into extra wire args...
        assert!(encode_submitb(&[spec(1, "x", &["a b"])]).is_err());
        // ...and an embedded newline would desynchronize the frame.
        assert!(encode_submitb(&[spec(1, "x\n2 y", &[])]).is_err());
        assert!(encode_submitb(&[spec(1, "", &[])]).is_err());
        assert!(encode_submitb(&[spec(1, "ok", &["fine"])]).is_ok());
    }

    // -- live TCP ------------------------------------------------------

    #[test]
    fn tcp_submit_roundtrip() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        let r = client.run(1, "sleep0", &[]).unwrap();
        assert!(r.ok);
        assert_eq!(r.id, 1);
    }

    #[test]
    fn tcp_reports_failures() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        let r = client.run(2, "fail", &[]).unwrap();
        assert!(!r.ok);
        assert!(r.error.contains("requested failure"));
    }

    #[test]
    fn tcp_pipeline_many_submissions() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        let n = 200;
        for i in 0..n {
            client.submit(i, "sleep0", &[]).unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..n {
            let r = client.next_result().unwrap();
            assert!(r.ok);
            seen.insert(r.id);
        }
        assert_eq!(seen.len(), n as usize);
    }

    #[test]
    fn tcp_batched_frames_roundtrip_mixed_outcomes() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        let tasks: Vec<TaskSpec> = (0..120u64)
            .map(|i| spec(i, if i % 10 == 0 { "fail" } else { "sleep0" }, &[]))
            .collect();
        client.submit_batch(&tasks).unwrap();
        let mut seen = std::collections::HashMap::new();
        for _ in 0..tasks.len() {
            let r = client.next_result().unwrap();
            seen.insert(r.id, r.ok);
        }
        assert_eq!(seen.len(), tasks.len(), "every frame task acked once");
        for i in 0..120u64 {
            assert_eq!(seen[&i], i % 10 != 0, "task {i}");
        }
    }

    #[test]
    fn tcp_mixed_legacy_and_framed_submissions() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        client.submit(1000, "sleep0", &[]).unwrap();
        client
            .submit_batch(&(0..50u64).map(|i| spec(i, "sleep0", &[])).collect::<Vec<_>>())
            .unwrap();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..51 {
            let r = client.next_result().unwrap();
            assert!(r.ok);
            seen.insert(r.id);
        }
        assert!(seen.contains(&1000), "legacy RESULT ack decoded");
        assert_eq!(seen.len(), 51);
    }

    #[test]
    fn autobatch_coalesces_singles_into_frames() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr())
            .unwrap()
            .with_autobatch(8, Duration::from_secs(60));
        // 20 buffered singles with a 60 s age threshold: only the batch
        // cut-off fires, shipping two full frames; 4 tasks stay
        // buffered until the explicit flush.
        for i in 0..20u64 {
            client.submit_buffered(spec(i, "sleep0", &[])).unwrap();
        }
        assert_eq!(
            client.submit_buf.as_ref().unwrap().buf.lock().unwrap().len(),
            4,
            "two full frames shipped, remainder still buffered"
        );
        client.flush().unwrap();
        assert!(client
            .submit_buf
            .as_ref()
            .unwrap()
            .buf
            .lock()
            .unwrap()
            .is_empty());
        let mut seen = std::collections::HashSet::new();
        for _ in 0..20 {
            let r = client.next_result().unwrap();
            assert!(r.ok);
            seen.insert(r.id);
        }
        assert_eq!(seen.len(), 20, "every buffered task acked once");
    }

    #[test]
    fn autobatch_zero_age_ships_immediately() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr())
            .unwrap()
            .with_autobatch(100, Duration::ZERO);
        // Age threshold zero: the push itself is already due, so the
        // task ships without filling the batch and without flush().
        client.submit_buffered(spec(1, "sleep0", &[])).unwrap();
        let r = client.next_result().unwrap();
        assert!(r.ok);
        assert_eq!(r.id, 1);
    }

    #[test]
    fn submit_buffered_rejects_malformed_specs_before_buffering() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr())
            .unwrap()
            .with_autobatch(8, Duration::from_secs(60));
        // A whitespace executable must fail the submit call itself —
        // never reach the buffer, where it would poison a whole frame
        // at cut time with no caller to report to.
        assert!(client.submit_buffered(spec(1, "bad exe", &[])).is_err());
        assert!(client
            .submit_buf
            .as_ref()
            .unwrap()
            .buf
            .lock()
            .unwrap()
            .is_empty());
    }

    #[test]
    fn autobatch_timer_flushes_aged_frames_without_client_calls() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr())
            .unwrap()
            .with_autobatch_timer(100, Duration::from_millis(30));
        client.submit_buffered(spec(5, "sleep0", &[])).unwrap();
        // No further client calls: the timer thread alone must cut the
        // frame once the 30 ms age threshold passes.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let empty = client
                .submit_buf
                .as_ref()
                .unwrap()
                .buf
                .lock()
                .unwrap()
                .is_empty();
            if empty {
                break;
            }
            assert!(Instant::now() < deadline, "timer never flushed the frame");
            std::thread::sleep(Duration::from_millis(5));
        }
        let r = client.next_result().unwrap();
        assert!(r.ok);
        assert_eq!(r.id, 5);
    }

    #[test]
    fn autobatch_timer_shutdown_joins_cleanly() {
        let (_svc, server) = start_svc();
        let client = FalkonClient::connect(server.addr())
            .unwrap()
            .with_autobatch_timer(100, Duration::from_secs(60));
        // Drop must interrupt the 60 s age wait and join the timer
        // thread without hanging.
        drop(client);
    }

    #[test]
    fn next_result_flushes_buffered_submits() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr())
            .unwrap()
            .with_autobatch(100, Duration::from_secs(60));
        // Neither cut-off fires; the blocking read must flush or it
        // would deadlock waiting for a task the server never saw.
        client.submit_buffered(spec(9, "sleep0", &[])).unwrap();
        let r = client.next_result().unwrap();
        assert_eq!(r.id, 9);
    }

    #[test]
    fn tcp_stats_query() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        client.run(1, "sleep0", &[]).unwrap();
        let (submitted, completed, failed, _q, execs) = client.stats().unwrap();
        assert_eq!(submitted, 1);
        assert_eq!(completed, 1);
        assert_eq!(failed, 0);
        assert_eq!(execs, 2);
    }
}
