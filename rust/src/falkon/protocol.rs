//! Falkon network endpoint: the client-facing interface (the paper's
//! Web-Services interface analogue) as a TCP protocol with batched,
//! count-prefixed frames.
//!
//! Frame grammar (UTF-8 lines; `<n>` is a decimal count prefixing the
//! frame body — see DESIGN.md §4.1 for ordering/ack guarantees):
//!
//! ```text
//! C->S:  SUBMIT <id> <executable> [args...]          single-task (legacy)
//! C->S:  SUBMITB <n>                                 batched submit frame
//!        <id> <executable> [args...]                 x n task lines
//! S->C:  RESULT <id> <ok|err> <exec_us> <wait_us> [error...]
//! S->C:  DONEB <n>                                   batched ack frame
//!        <id> <ok|err> <exec_us> <wait_us> [error...]   x n status lines
//! C->S:  STATS
//! S->C:  STATS <submitted> <completed> <failed> <queue> <executors>
//! C->S:  QUIT
//! ```
//!
//! A `SUBMITB` frame enters the service through one
//! [`FalkonService::submit_batch`] call (one sharded-queue push for the
//! whole frame) instead of one queue operation per line. Completions are
//! still per-task; the server coalesces whatever acks are ready at write
//! time into one `DONEB` frame (opportunistic batching — no completion
//! waits for its frame peers). Single-line `SUBMIT` requests keep their
//! legacy `RESULT`-line acks so old clients work unchanged.
//!
//! Frame *cut-off* decisions — when a stream of singles becomes a frame
//! — are the policy core's [`crate::policy::FrameCoalescer`]: the
//! server's ack path runs it with a zero age threshold (flush
//! combining, frames capped at [`MAX_FRAME_TASKS`]), and the client's
//! optional [`FalkonClient::with_autobatch`] buffer runs it with a real
//! batch/age window (the Nagle-style submit side).
//!
//! ## Binary framing (wire grammar v2)
//!
//! The text grammar above pays a `format!`/`parse` round trip per task.
//! A connection can upgrade to length-prefixed little-endian binary
//! frames by sending the magic line [`BIN_MAGIC`] as its *first*
//! request; a v2 server answers with the [`BIN_ACK`] line and both
//! sides switch, while a legacy server just closes the connection (its
//! "bad request" path), which a client treats as "reconnect in text
//! mode" — see [`FalkonClient::connect_preferring_binary`]. After the
//! upgrade every frame is:
//!
//! ```text
//! [u32 len] [u8 opcode] [payload of len-1 bytes]     all integers LE
//! SUBMITB (op 1), C->S:  u32 n, then per task:
//!     u64 id, u16 exe_len + exe bytes, u16 argc,
//!     per arg: u16 len + bytes
//! DONEB (op 2), S->C:    u32 n, then per result:
//!     u64 id, u8 ok, u64 exec_us, u64 wait_us, u32 err_len + err bytes
//! STATS (op 3), C->S:    empty payload
//! STATSR (op 4), S->C:   5 x u64 (submitted completed failed queue execs)
//! QUIT (op 5), C->S:     empty payload
//! SCRAPE (op 6), C->S:   empty payload
//! SCRAPER (op 7), S->C:  u16 version, u8 n_sections, then per section:
//!     u8 id, u32 len, len payload bytes (unknown ids skipped)
//! ```
//!
//! `SCRAPE` is the full-telemetry sibling of `STATS`: the reply carries
//! a versioned [`crate::telemetry::MetricsSnapshot`] — service gauges
//! (section 1), counter totals (section 2), and log2 histogram buckets
//! (section 3) — with metric *names* on the wire so decoders never
//! misattribute a renumbered counter slot. Decoders skip sections they
//! do not recognize, so new sections ship without a version bump.
//!
//! Encode targets a reusable per-connection buffer (zero per-task
//! allocations); server-side decode borrows executable/arg bytes
//! straight from the frame payload and moves them into pooled arg
//! spines ([`FalkonService::arg_vec`]). v2 keeps v1's token validation
//! (non-empty, whitespace-free wire words) so a spec is valid or
//! invalid independently of the negotiated framing, and flattens
//! newlines in error text the same way. See DESIGN.md §10.1–10.2 for
//! the negotiation state machine.
//!
//! Executors remain in-process (this testbed is one host); the endpoint
//! exists so remote clients — and the fig12 "submit from a different
//! host" benchmark — exercise a real network hop on the submit path.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::policy::{FrameCoalescer, FramePolicy, RealClock};
use crate::providers::{AppTask, TaskDone};
use crate::telemetry::counters::{self, Counter, Hist};
use crate::telemetry::{MetricsSnapshot, ServiceSection};

use super::service::FalkonService;

/// Upper bound on `<n>` in a `SUBMITB`/`DONEB` header: a defense against
/// absurd counts from malformed or hostile peers (the paper's service
/// queues 1.5M tasks total; no single frame needs more than this).
pub const MAX_FRAME_TASKS: usize = 65_536;

/// One task as it crosses the wire (the client-side mirror of the
/// `SUBMITB` task line `<id> <executable> [args...]`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskSpec {
    /// Client-chosen task id, echoed back in the ack.
    pub id: u64,
    /// Logical executable name (resolved by the server's app registry).
    pub executable: String,
    /// Command-line words after the executable (no embedded whitespace).
    pub args: Vec<String>,
}

/// One result line from the service (a `RESULT` line or one `DONEB`
/// status line — both carry the same fields).
#[derive(Debug, Clone, PartialEq)]
pub struct RemoteResult {
    /// The id the task was submitted with.
    pub id: u64,
    /// True when the task ran to success.
    pub ok: bool,
    /// Executor-side execution time in microseconds.
    pub exec_us: u64,
    /// Service-queue wait time in microseconds.
    pub wait_us: u64,
    /// Error message for failed tasks (newlines flattened; empty on ok).
    pub error: String,
}

// ---------------------------------------------------------------------
// Frame encode/decode (pure; unit-testable without sockets)
// ---------------------------------------------------------------------

/// Encode a `SUBMITB` frame: the `SUBMITB <n>` header line followed by
/// `n` task lines. Fails if an executable or arg contains whitespace —
/// an embedded space would silently split into extra wire args, and an
/// embedded newline would desynchronize the frame (the receiver counts
/// lines), so both are rejected before anything touches the wire.
pub fn encode_submitb(tasks: &[TaskSpec]) -> Result<String> {
    let mut out = format!("SUBMITB {}\n", tasks.len());
    for t in tasks {
        ensure_wire_word(&t.executable, "executable")?;
        out.push_str(&t.id.to_string());
        out.push(' ');
        out.push_str(&t.executable);
        for a in &t.args {
            ensure_wire_word(a, "arg")?;
            out.push(' ');
            out.push_str(a);
        }
        out.push('\n');
    }
    Ok(out)
}

/// A wire word is one non-empty token of a task line: no whitespace.
fn ensure_wire_word(s: &str, what: &str) -> Result<()> {
    if s.is_empty() || s.contains(char::is_whitespace) {
        bail!("task {what} {s:?} must be non-empty and whitespace-free");
    }
    Ok(())
}

/// Decode the body of a `SUBMITB` frame — the `n` task lines following
/// an already-consumed header. Fails on a count above
/// [`MAX_FRAME_TASKS`], on EOF before `n` lines arrive (truncated
/// frame), and on malformed task lines.
pub fn decode_submitb_body(n: usize, reader: &mut impl BufRead) -> Result<Vec<TaskSpec>> {
    if n > MAX_FRAME_TASKS {
        bail!("SUBMITB frame of {n} tasks exceeds the {MAX_FRAME_TASKS} cap");
    }
    let mut tasks = Vec::with_capacity(n);
    let mut line = String::new();
    for i in 0..n {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            bail!("truncated SUBMITB frame: got {i} of {n} task lines");
        }
        let mut parts = line.trim().split(' ').filter(|s| !s.is_empty());
        let id: u64 = parts
            .next()
            .context("SUBMITB task line missing id")?
            .parse()
            .context("SUBMITB task line: bad id")?;
        let executable = parts
            .next()
            .context("SUBMITB task line missing executable")?
            .to_string();
        let args = parts.map(|s| s.to_string()).collect();
        tasks.push(TaskSpec { id, executable, args });
    }
    Ok(tasks)
}

/// Render one status line (shared by `RESULT` acks, which prefix it with
/// the keyword, and `DONEB` body lines).
fn status_line(r: &RemoteResult) -> String {
    let status = if r.ok { "ok" } else { "err" };
    let err = r.error.replace('\n', " ");
    format!("{} {} {} {} {}\n", r.id, status, r.exec_us, r.wait_us, err)
}

/// Encode a `DONEB` frame: the `DONEB <n>` header line followed by `n`
/// status lines.
pub fn encode_doneb(results: &[RemoteResult]) -> String {
    let mut out = format!("DONEB {}\n", results.len());
    for r in results {
        out.push_str(&status_line(r));
    }
    out
}

/// Parse the fields of one status line (after any keyword prefix has
/// been stripped): `<id> <ok|err> <exec_us> <wait_us> [error...]`.
fn parse_status_fields(fields: &str) -> Result<RemoteResult> {
    let parts: Vec<&str> = fields.trim().splitn(5, ' ').collect();
    if parts.len() < 4 {
        bail!("malformed status line: {fields:?}");
    }
    Ok(RemoteResult {
        id: parts[0].parse().context("status line: bad id")?,
        ok: parts[1] == "ok",
        exec_us: parts[2].parse().context("status line: bad exec_us")?,
        wait_us: parts[3].parse().context("status line: bad wait_us")?,
        error: parts.get(4).map(|s| s.trim_end()).unwrap_or("").to_string(),
    })
}

/// Decode the body of a `DONEB` frame — the `n` status lines following
/// an already-consumed header. Fails on an oversized count and on EOF
/// before `n` lines arrive (truncated frame).
pub fn decode_doneb_body(n: usize, reader: &mut impl BufRead) -> Result<Vec<RemoteResult>> {
    if n > MAX_FRAME_TASKS {
        bail!("DONEB frame of {n} results exceeds the {MAX_FRAME_TASKS} cap");
    }
    let mut results = Vec::with_capacity(n);
    let mut line = String::new();
    for i in 0..n {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            bail!("truncated DONEB frame: got {i} of {n} status lines");
        }
        results.push(parse_status_fields(&line)?);
    }
    Ok(results)
}

// ---------------------------------------------------------------------
// Binary wire protocol v2 (pure codec; unit/fuzz-testable without
// sockets)
// ---------------------------------------------------------------------

/// Magic preamble line a client sends as its first request to negotiate
/// binary framing. Chosen to parse as an unknown text request on legacy
/// servers (which then close the connection, signalling "text only").
pub const BIN_MAGIC: &str = "BINV2";

/// The server's acknowledgement line; everything after it is binary.
pub const BIN_ACK: &str = "BINV2 OK";

/// Upper bound on one binary frame (length prefix value). Defense
/// against hostile length prefixes: a max-size `SUBMITB` frame
/// ([`MAX_FRAME_TASKS`] tasks of ordinary specs) fits comfortably.
pub const MAX_BIN_FRAME_BYTES: usize = 64 << 20;

/// Binary opcodes (the byte after the length prefix).
pub const OP_SUBMITB: u8 = 1;
pub const OP_DONEB: u8 = 2;
pub const OP_STATS: u8 = 3;
pub const OP_STATS_REPLY: u8 = 4;
pub const OP_QUIT: u8 = 5;
pub const OP_SCRAPE: u8 = 6;
pub const OP_SCRAPE_REPLY: u8 = 7;

/// `SCRAPE` reply section ids. Unknown ids are skipped by length, so
/// new sections are backward compatible without a version bump.
pub const SEC_SERVICE: u8 = 1;
pub const SEC_COUNTERS: u8 = 2;
pub const SEC_HISTS: u8 = 3;

/// Begin a frame in `buf`: length placeholder + opcode. Must be paired
/// with [`finish_bin_frame`].
fn begin_bin_frame(buf: &mut Vec<u8>, op: u8) {
    buf.clear();
    buf.extend_from_slice(&[0u8; 4]);
    buf.push(op);
}

/// Patch the length prefix ([opcode + payload] bytes) into the frame
/// started by [`begin_bin_frame`].
fn finish_bin_frame(buf: &mut Vec<u8>) -> Result<()> {
    let body = buf.len() - 4;
    if body > MAX_BIN_FRAME_BYTES {
        bail!("binary frame of {body} bytes exceeds the {MAX_BIN_FRAME_BYTES} cap");
    }
    let len = (body as u32).to_le_bytes();
    buf[..4].copy_from_slice(&len);
    counters::incr(Counter::FramesEncoded);
    Ok(())
}

/// Append a u16-length-prefixed wire word (validated like the text
/// protocol's tokens, so framing never changes which specs are legal).
fn put_word16(buf: &mut Vec<u8>, s: &str, what: &str) -> Result<()> {
    ensure_wire_word(s, what)?;
    if s.len() > u16::MAX as usize {
        bail!("task {what} of {} bytes exceeds the u16 wire limit", s.len());
    }
    buf.extend_from_slice(&(s.len() as u16).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
    Ok(())
}

/// Encode a binary `SUBMITB` frame into `buf` (cleared first). The
/// buffer is the caller's reusable per-connection scratch: in the
/// steady state this performs zero allocations per task.
pub fn encode_submitb_bin(tasks: &[TaskSpec], buf: &mut Vec<u8>) -> Result<()> {
    if tasks.len() > MAX_FRAME_TASKS {
        bail!(
            "SUBMITB frame of {} tasks exceeds the {MAX_FRAME_TASKS} cap",
            tasks.len()
        );
    }
    begin_bin_frame(buf, OP_SUBMITB);
    counters::observe(Hist::FrameTasks, tasks.len() as u64);
    buf.extend_from_slice(&(tasks.len() as u32).to_le_bytes());
    for t in tasks {
        buf.extend_from_slice(&t.id.to_le_bytes());
        put_word16(buf, &t.executable, "executable")?;
        if t.args.len() > u16::MAX as usize {
            bail!("task arg count {} exceeds the u16 wire limit", t.args.len());
        }
        buf.extend_from_slice(&(t.args.len() as u16).to_le_bytes());
        for a in &t.args {
            put_word16(buf, a, "arg")?;
        }
    }
    finish_bin_frame(buf)
}

/// Encode a binary `DONEB` frame into `buf` (cleared first). Newlines
/// in error text are flattened to spaces for parity with the text
/// grammar; ok results (empty error) encode allocation-free.
pub fn encode_doneb_bin(results: &[RemoteResult], buf: &mut Vec<u8>) -> Result<()> {
    if results.len() > MAX_FRAME_TASKS {
        bail!(
            "DONEB frame of {} results exceeds the {MAX_FRAME_TASKS} cap",
            results.len()
        );
    }
    begin_bin_frame(buf, OP_DONEB);
    counters::observe(Hist::FrameTasks, results.len() as u64);
    buf.extend_from_slice(&(results.len() as u32).to_le_bytes());
    for r in results {
        buf.extend_from_slice(&r.id.to_le_bytes());
        buf.push(u8::from(r.ok));
        buf.extend_from_slice(&r.exec_us.to_le_bytes());
        buf.extend_from_slice(&r.wait_us.to_le_bytes());
        buf.extend_from_slice(&(r.error.len() as u32).to_le_bytes());
        if r.error.contains('\n') {
            buf.extend_from_slice(r.error.replace('\n', " ").as_bytes());
        } else {
            buf.extend_from_slice(r.error.as_bytes());
        }
    }
    finish_bin_frame(buf)
}

/// Encode a binary `STATS` request into `buf` (cleared first).
pub fn encode_stats_req_bin(buf: &mut Vec<u8>) {
    begin_bin_frame(buf, OP_STATS);
    finish_bin_frame(buf).expect("empty frame fits");
}

/// Encode a binary `STATS` reply into `buf` (cleared first).
pub fn encode_stats_reply_bin(stats: &[u64; 5], buf: &mut Vec<u8>) {
    begin_bin_frame(buf, OP_STATS_REPLY);
    for v in stats {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    finish_bin_frame(buf).expect("40-byte frame fits");
}

/// Encode a binary `SCRAPE` request into `buf` (cleared first).
pub fn encode_scrape_req_bin(buf: &mut Vec<u8>) {
    begin_bin_frame(buf, OP_SCRAPE);
    finish_bin_frame(buf).expect("empty frame fits");
}

/// Begin a length-prefixed `SCRAPE` section: id + u32 length
/// placeholder. Returns the payload start for [`finish_section`].
fn begin_section(buf: &mut Vec<u8>, id: u8) -> usize {
    buf.push(id);
    buf.extend_from_slice(&[0u8; 4]);
    buf.len()
}

/// Patch the section length written by [`begin_section`].
fn finish_section(buf: &mut Vec<u8>, start: usize) {
    let len = (buf.len() - start) as u32;
    buf[start - 4..start].copy_from_slice(&len.to_le_bytes());
}

/// Encode a binary `SCRAPE` reply into `buf` (cleared first): version,
/// section count, then the service / counters / histograms sections.
pub fn encode_scrape_reply_bin(snap: &MetricsSnapshot, buf: &mut Vec<u8>) -> Result<()> {
    begin_bin_frame(buf, OP_SCRAPE_REPLY);
    buf.extend_from_slice(&snap.version.to_le_bytes());
    buf.push(3); // n_sections
    let sv = &snap.service;
    let start = begin_section(buf, SEC_SERVICE);
    for v in [
        sv.uptime_us,
        sv.submitted,
        sv.completed,
        sv.failed,
        sv.queue_len,
        sv.peak_queue,
        sv.live_executors,
        sv.peak_executors,
        sv.busy_us,
    ] {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    finish_section(buf, start);
    let start = begin_section(buf, SEC_COUNTERS);
    buf.extend_from_slice(&(snap.counters.counters.len() as u32).to_le_bytes());
    for (name, total) in &snap.counters.counters {
        put_word16(buf, name, "counter name")?;
        buf.extend_from_slice(&total.to_le_bytes());
    }
    finish_section(buf, start);
    let start = begin_section(buf, SEC_HISTS);
    buf.extend_from_slice(&(snap.counters.hists.len() as u32).to_le_bytes());
    for (name, buckets) in &snap.counters.hists {
        put_word16(buf, name, "histogram name")?;
        if buckets.len() > u16::MAX as usize {
            bail!("histogram {name} has {} buckets", buckets.len());
        }
        buf.extend_from_slice(&(buckets.len() as u16).to_le_bytes());
        for b in buckets {
            buf.extend_from_slice(&b.to_le_bytes());
        }
    }
    finish_section(buf, start);
    finish_bin_frame(buf)
}

/// Cap on metric entries per `SCRAPE` section: defense against hostile
/// counts (the registry ships a few dozen).
const MAX_SCRAPE_METRICS: usize = 4096;

/// Decode a binary `SCRAPE` reply payload. Unknown sections are
/// skipped by their length prefix — a newer server's extra sections
/// never break an older client.
pub fn decode_scrape_reply_bin(payload: &[u8]) -> Result<MetricsSnapshot> {
    let mut cur = BinCursor::new(payload);
    let version = cur.u16()?;
    let n_sections = cur.u8()?;
    let mut snap = MetricsSnapshot { version, ..MetricsSnapshot::default() };
    for _ in 0..n_sections {
        let id = cur.u8()?;
        let len = cur.u32()? as usize;
        let mut sec = BinCursor::new(cur.take(len)?);
        match id {
            SEC_SERVICE => {
                let mut v = [0u64; 9];
                for slot in &mut v {
                    *slot = sec.u64()?;
                }
                if !sec.is_empty() {
                    bail!("trailing bytes in SCRAPE service section");
                }
                snap.service = ServiceSection {
                    uptime_us: v[0],
                    submitted: v[1],
                    completed: v[2],
                    failed: v[3],
                    queue_len: v[4],
                    peak_queue: v[5],
                    live_executors: v[6],
                    peak_executors: v[7],
                    busy_us: v[8],
                };
            }
            SEC_COUNTERS => {
                let n = sec.u32()? as usize;
                if n > MAX_SCRAPE_METRICS {
                    bail!("SCRAPE counter section of {n} entries");
                }
                let mut out = Vec::with_capacity(n);
                for _ in 0..n {
                    let name = sec.str16()?.to_string();
                    out.push((name, sec.u64()?));
                }
                if !sec.is_empty() {
                    bail!("trailing bytes in SCRAPE counter section");
                }
                snap.counters.counters = out;
            }
            SEC_HISTS => {
                let n = sec.u32()? as usize;
                if n > MAX_SCRAPE_METRICS {
                    bail!("SCRAPE histogram section of {n} entries");
                }
                let mut out = Vec::with_capacity(n);
                for _ in 0..n {
                    let name = sec.str16()?.to_string();
                    let nb = sec.u16()? as usize;
                    let mut buckets = Vec::with_capacity(nb);
                    for _ in 0..nb {
                        buckets.push(sec.u64()?);
                    }
                    out.push((name, buckets));
                }
                if !sec.is_empty() {
                    bail!("trailing bytes in SCRAPE histogram section");
                }
                snap.counters.hists = out;
            }
            _ => {} // forward compatibility: unknown section, skipped
        }
    }
    if !cur.is_empty() {
        bail!("trailing bytes after SCRAPE reply sections");
    }
    Ok(snap)
}

/// A borrowing cursor over one frame payload. Every read is
/// bounds-checked: truncated or garbage payloads produce errors, never
/// panics or over-reads.
struct BinCursor<'a> {
    b: &'a [u8],
}

impl<'a> BinCursor<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() < n {
            bail!(
                "truncated binary payload: wanted {n} bytes, {} left",
                self.b.len()
            );
        }
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b: [u8; 2] = self.take(2)?.try_into().context("2-byte field")?;
        Ok(u16::from_le_bytes(b))
    }

    fn u32(&mut self) -> Result<u32> {
        let b: [u8; 4] = self.take(4)?.try_into().context("4-byte field")?;
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self) -> Result<u64> {
        let b: [u8; 8] = self.take(8)?.try_into().context("8-byte field")?;
        Ok(u64::from_le_bytes(b))
    }

    /// A u16-length-prefixed string, borrowed from the payload.
    fn str16(&mut self) -> Result<&'a str> {
        let n = self.u16()? as usize;
        std::str::from_utf8(self.take(n)?).context("non-UTF-8 wire string")
    }

    /// A u32-length-prefixed string (error text), borrowed.
    fn str32(&mut self) -> Result<&'a str> {
        let n = self.u32()? as usize;
        std::str::from_utf8(self.take(n)?).context("non-UTF-8 wire string")
    }

    fn is_empty(&self) -> bool {
        self.b.is_empty()
    }
}

/// Streaming decoder for a binary `SUBMITB` payload: yields one task at
/// a time with the executable and args **borrowed from the read
/// buffer** — the server materializes them straight into pooled arg
/// spines without an intermediate `TaskSpec`.
pub struct SubmitbBinIter<'a> {
    cur: BinCursor<'a>,
    remaining: usize,
}

impl<'a> SubmitbBinIter<'a> {
    /// Parse the frame header (task count) of `payload` (the bytes
    /// after the opcode).
    pub fn parse(payload: &'a [u8]) -> Result<Self> {
        let mut cur = BinCursor::new(payload);
        let n = cur.u32()? as usize;
        if n > MAX_FRAME_TASKS {
            bail!("SUBMITB frame of {n} tasks exceeds the {MAX_FRAME_TASKS} cap");
        }
        Ok(Self { cur, remaining: n })
    }

    /// Tasks not yet decoded.
    pub fn remaining(&self) -> usize {
        self.remaining
    }

    /// Decode the next task: clears `args`, fills it with the task's
    /// arguments, and returns `(id, executable)`. `Ok(None)` when the
    /// frame is exhausted (trailing bytes after the last task are an
    /// error — a desynchronized peer, not padding).
    pub fn next_task(&mut self, args: &mut Vec<String>) -> Result<Option<(u64, &'a str)>> {
        args.clear();
        if self.remaining == 0 {
            if !self.cur.is_empty() {
                bail!("trailing bytes after SUBMITB frame body");
            }
            return Ok(None);
        }
        self.remaining -= 1;
        let id = self.cur.u64()?;
        let exe = self.cur.str16()?;
        ensure_wire_word(exe, "executable")?;
        let argc = self.cur.u16()? as usize;
        args.reserve(argc);
        for _ in 0..argc {
            let a = self.cur.str16()?;
            ensure_wire_word(a, "arg")?;
            args.push(a.to_string());
        }
        Ok(Some((id, exe)))
    }
}

/// Decode a whole binary `SUBMITB` payload into owned [`TaskSpec`]s
/// (test/differential convenience; the server uses the borrowing
/// [`SubmitbBinIter`]).
pub fn decode_submitb_bin(payload: &[u8]) -> Result<Vec<TaskSpec>> {
    let mut iter = SubmitbBinIter::parse(payload)?;
    let mut out = Vec::with_capacity(iter.remaining());
    let mut args = Vec::new();
    while let Some((id, exe)) = iter.next_task(&mut args)? {
        out.push(TaskSpec {
            id,
            executable: exe.to_string(),
            args: std::mem::take(&mut args),
        });
    }
    Ok(out)
}

/// Decode a binary `DONEB` payload into results.
pub fn decode_doneb_bin(payload: &[u8]) -> Result<Vec<RemoteResult>> {
    let mut cur = BinCursor::new(payload);
    let n = cur.u32()? as usize;
    if n > MAX_FRAME_TASKS {
        bail!("DONEB frame of {n} results exceeds the {MAX_FRAME_TASKS} cap");
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let id = cur.u64()?;
        let ok = cur.u8()? != 0;
        let exec_us = cur.u64()?;
        let wait_us = cur.u64()?;
        let error = cur.str32()?.to_string();
        out.push(RemoteResult { id, ok, exec_us, wait_us, error });
    }
    if !cur.is_empty() {
        bail!("trailing bytes after DONEB frame body");
    }
    Ok(out)
}

/// Decode a binary `STATS` reply payload.
pub fn decode_stats_reply_bin(payload: &[u8]) -> Result<[u64; 5]> {
    let mut cur = BinCursor::new(payload);
    let mut out = [0u64; 5];
    for v in &mut out {
        *v = cur.u64()?;
    }
    if !cur.is_empty() {
        bail!("trailing bytes after STATS reply");
    }
    Ok(out)
}

/// Read one binary frame: returns its opcode with the payload in `buf`
/// (cleared and reused across frames), or `Ok(None)` on a clean close
/// (EOF before any byte of the next frame). Truncation mid-frame and
/// hostile length prefixes are errors.
pub fn read_bin_frame(r: &mut impl Read, buf: &mut Vec<u8>) -> Result<Option<u8>> {
    let mut len4 = [0u8; 4];
    let mut first = [0u8; 1];
    match r.read(&mut first) {
        Ok(0) => return Ok(None), // clean close at a frame boundary
        Ok(_) => len4[0] = first[0],
        Err(e) => return Err(e).context("read binary frame length"),
    }
    r.read_exact(&mut len4[1..])
        .context("truncated binary frame (length prefix)")?;
    let len = u32::from_le_bytes(len4) as usize;
    if len == 0 {
        bail!("binary frame with no opcode");
    }
    if len > MAX_BIN_FRAME_BYTES {
        bail!("binary frame of {len} bytes exceeds the {MAX_BIN_FRAME_BYTES} cap");
    }
    let mut op = [0u8; 1];
    r.read_exact(&mut op)
        .context("truncated binary frame (opcode)")?;
    buf.clear();
    buf.resize(len - 1, 0);
    r.read_exact(buf).context("truncated binary frame (body)")?;
    counters::incr(Counter::FramesDecoded);
    Ok(Some(op[0]))
}

// ---------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------

/// TCP front-end for a Falkon service.
pub struct FalkonTcpServer {
    addr: std::net::SocketAddr,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    shutdown: Arc<std::sync::atomic::AtomicBool>,
}

impl FalkonTcpServer {
    /// Bind and serve (background threads). Use port 0 for ephemeral.
    pub fn start(service: Arc<FalkonService>, bind: &str) -> Result<Self> {
        let listener = TcpListener::bind(bind).context("bind falkon endpoint")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let sd = Arc::clone(&shutdown);
        let accept_thread = std::thread::Builder::new()
            .name("falkon-accept".into())
            .spawn(move || {
                loop {
                    if sd.load(Ordering::SeqCst) {
                        return;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let svc = Arc::clone(&service);
                            std::thread::spawn(move || {
                                let _ = serve_conn(stream, svc);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => return,
                    }
                }
            })?;
        Ok(Self { addr, accept_thread: Some(accept_thread), shutdown })
    }

    /// The bound address (useful with ephemeral port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }
}

impl Drop for FalkonTcpServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

/// Per-connection shared state: the write half plus the pending-ack
/// coalescer that cuts completions into `DONEB` frames.
///
/// The cut-off rule is the policy core's [`FrameCoalescer`] with a zero
/// age threshold: an ack never *waits* for peers — every completion
/// triggers a flush — but completions that accumulate while another
/// completion holds the write lock coalesce into one frame (flush
/// combining). The coalescer's batch cap also guarantees no `DONEB`
/// frame ever exceeds [`MAX_FRAME_TASKS`], which an unbounded ack
/// buffer could previously overflow under extreme backlog.
struct ConnState {
    writer: Mutex<ConnWriter>,
    acks: Mutex<FrameCoalescer<RealClock, RemoteResult>>,
}

/// The write half of a connection plus its framing mode and the reusable
/// encode buffer (binary `DONEB` frames encode with zero per-task
/// allocations into this scratch).
struct ConnWriter {
    stream: TcpStream,
    buf: Vec<u8>,
    binary: bool,
}

impl ConnState {
    /// Queue one completion and flush whatever frames are due.
    fn push_ack(&self, r: RemoteResult) {
        let full = self.acks.lock().unwrap().push(r, Instant::now());
        if let Some(frame) = full {
            self.write_doneb(&frame);
        }
        self.flush_acks();
    }

    fn flush_acks(&self) {
        loop {
            let batch = self.acks.lock().unwrap().take_due(Instant::now());
            let Some(batch) = batch else { return };
            self.write_doneb(&batch);
            // Loop: completions that arrived during the write get their
            // own frame now instead of waiting for the next completion.
        }
    }

    fn write_doneb(&self, batch: &[RemoteResult]) {
        let Ok(mut w) = self.writer.lock() else { return };
        let ConnWriter { stream, buf, binary } = &mut *w;
        if *binary {
            if encode_doneb_bin(batch, buf).is_ok() {
                let _ = stream.write_all(buf);
            }
        } else {
            let frame = encode_doneb(batch);
            let _ = stream.write_all(frame.as_bytes());
        }
    }
}

fn serve_conn(stream: TcpStream, svc: Arc<FalkonService>) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let conn = Arc::new(ConnState {
        writer: Mutex::new(ConnWriter { stream, buf: Vec::new(), binary: false }),
        acks: Mutex::new(FrameCoalescer::new(FramePolicy {
            max_tasks: MAX_FRAME_TASKS,
            max_age: Duration::ZERO,
        })),
    });
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // peer closed
        }
        let parts: Vec<&str> = line.trim().split(' ').collect();
        match parts.first().copied() {
            Some("SUBMIT") if parts.len() >= 3 => {
                let id: u64 = parts[1].parse().context("bad id")?;
                let executable = parts[2].to_string();
                let args: Vec<String> =
                    parts[3..].iter().map(|s| s.to_string()).collect();
                let task = app_task(TaskSpec { id, executable, args }, &peer);
                let c = Arc::clone(&conn);
                svc.submit(
                    task,
                    Box::new(move |r| {
                        // Legacy single-task ack: one RESULT line.
                        let msg = format!("RESULT {}", status_line(&remote(r)));
                        if let Ok(mut s) = c.writer.lock() {
                            let _ = s.stream.write_all(msg.as_bytes());
                        }
                    }),
                );
            }
            Some("SUBMITB") if parts.len() == 2 => {
                let n: usize = parts[1].parse().context("bad SUBMITB count")?;
                let specs = decode_submitb_body(n, &mut reader)?;
                // One service call for the whole frame: the batched
                // queue push amortizes locks/wakeups across the frame.
                let batch: Vec<(AppTask, TaskDone)> = specs
                    .into_iter()
                    .map(|spec| {
                        let task = app_task(spec, &peer);
                        let c = Arc::clone(&conn);
                        let done: TaskDone =
                            Box::new(move |r| c.push_ack(remote(r)));
                        (task, done)
                    })
                    .collect();
                svc.submit_batch(batch);
            }
            Some("STATS") => {
                let st = svc.stats();
                let msg = format!(
                    "STATS {} {} {} {} {}\n",
                    st.submitted.load(Ordering::SeqCst),
                    st.completed.load(Ordering::SeqCst),
                    st.failed.load(Ordering::SeqCst),
                    svc.queue_len(),
                    svc.live_executors(),
                );
                conn.writer.lock().unwrap().stream.write_all(msg.as_bytes())?;
            }
            Some("QUIT") => return Ok(()),
            Some(BIN_MAGIC) if parts.len() == 1 => {
                return serve_conn_bin(reader, conn, svc, peer);
            }
            other => bail!("bad request {other:?}"),
        }
    }
}

/// Binary-mode connection loop, entered after the [`BIN_MAGIC`]
/// preamble. Acks the upgrade, flips the writer to binary framing, then
/// reads length-prefixed frames. `SUBMITB` payloads are decoded
/// borrowing from the read buffer, with arg spines drawn from the
/// service's pool — zero steady-state allocations per task on this
/// path.
fn serve_conn_bin(
    mut reader: BufReader<TcpStream>,
    conn: Arc<ConnState>,
    svc: Arc<FalkonService>,
    peer: Option<std::net::SocketAddr>,
) -> Result<()> {
    {
        let mut w = conn.writer.lock().unwrap();
        w.binary = true;
        w.stream.write_all(format!("{BIN_ACK}\n").as_bytes())?;
    }
    let mut payload = Vec::new();
    loop {
        let Some(op) = read_bin_frame(&mut reader, &mut payload)? else {
            return Ok(()); // peer closed at a frame boundary
        };
        match op {
            OP_SUBMITB => {
                let mut iter = SubmitbBinIter::parse(&payload)?;
                let mut batch: Vec<(AppTask, TaskDone)> =
                    Vec::with_capacity(iter.remaining());
                let mut args = svc.arg_vec();
                while let Some((id, exe)) = iter.next_task(&mut args)? {
                    let task = AppTask {
                        id,
                        key: format!("tcp/{peer:?}/{id}"),
                        executable: exe.to_string(),
                        args: std::mem::replace(&mut args, svc.arg_vec()),
                        inputs: vec![],
                        outputs: vec![],
                    };
                    let c = Arc::clone(&conn);
                    let done: TaskDone = Box::new(move |r| c.push_ack(remote(r)));
                    batch.push((task, done));
                }
                svc.recycle_args(args);
                svc.submit_batch(batch);
            }
            OP_STATS => {
                let st = svc.stats();
                let stats = [
                    st.submitted.load(Ordering::SeqCst),
                    st.completed.load(Ordering::SeqCst),
                    st.failed.load(Ordering::SeqCst),
                    svc.queue_len() as u64,
                    svc.live_executors() as u64,
                ];
                let mut w = conn.writer.lock().unwrap();
                let ConnWriter { stream, buf, .. } = &mut *w;
                encode_stats_reply_bin(&stats, buf);
                stream.write_all(buf)?;
            }
            OP_SCRAPE => {
                let snap = svc.scrape_snapshot();
                let mut w = conn.writer.lock().unwrap();
                let ConnWriter { stream, buf, .. } = &mut *w;
                encode_scrape_reply_bin(&snap, buf)?;
                stream.write_all(buf)?;
            }
            OP_QUIT => return Ok(()),
            other => bail!("bad binary opcode {other}"),
        }
    }
}

/// Build the server-side [`AppTask`] for a wire task.
fn app_task(spec: TaskSpec, peer: &Option<std::net::SocketAddr>) -> AppTask {
    AppTask {
        id: spec.id,
        key: format!("tcp/{peer:?}/{}", spec.id),
        executable: spec.executable,
        args: spec.args,
        inputs: vec![],
        outputs: vec![],
    }
}

/// Convert a service [`crate::providers::TaskResult`] to its wire form.
fn remote(r: crate::providers::TaskResult) -> RemoteResult {
    RemoteResult {
        id: r.id,
        ok: r.ok,
        exec_us: r.exec_us,
        wait_us: r.wait_us,
        error: r.error.unwrap_or_default(),
    }
}

// ---------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------

/// Shared autobatch state: the submit coalescer plus the condvar the
/// optional timer thread sleeps on.
struct SubmitBuf {
    buf: Mutex<FrameCoalescer<RealClock, TaskSpec>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// A blocking TCP client for the Falkon endpoint. Decodes both legacy
/// `RESULT` lines and batched `DONEB` frames into a single result
/// stream.
///
/// With [`FalkonClient::with_autobatch`], a stream of single
/// [`FalkonClient::submit_buffered`] calls is Nagle-style coalesced
/// into `SUBMITB` frames by the policy core's [`FrameCoalescer`]: a
/// frame ships when the batch cap fills or the oldest buffered task
/// crosses the age threshold (checked on every client call), and
/// [`FalkonClient::flush`] is the escape hatch. Reading results
/// auto-flushes first, so a buffered submit can never deadlock against
/// its own ack. [`FalkonClient::with_autobatch_timer`] additionally
/// spawns a timer thread so age-based flushes fire even when the
/// caller makes no further client calls; dropping the client shuts the
/// thread down and joins it.
pub struct FalkonClient {
    reader: BufReader<TcpStream>,
    /// Write half, lockable so the autobatch timer thread can ship
    /// frames concurrently with caller writes (frames never
    /// interleave mid-write). The framing mode and reusable encode
    /// buffer live inside the lock so both writers share them.
    writer: Arc<Mutex<ClientWriter>>,
    /// Read-path mirror of the negotiated framing mode.
    binary: bool,
    /// Reusable read-side payload buffer for binary frames.
    frame_buf: Vec<u8>,
    /// Results decoded from a `DONEB` frame (or stashed while waiting
    /// for a STATS reply) but not yet handed to the caller.
    pending: VecDeque<RemoteResult>,
    /// Nagle-style submit buffer (None until `with_autobatch`).
    submit_buf: Option<Arc<SubmitBuf>>,
    /// Age-flush timer thread (None until `with_autobatch_timer`).
    timer: Option<std::thread::JoinHandle<()>>,
}

/// The client's write half: stream + negotiated framing mode + the
/// reusable per-connection encode buffer (binary `SUBMITB` frames
/// encode here with zero per-task allocations).
struct ClientWriter {
    stream: TcpStream,
    enc: Vec<u8>,
    binary: bool,
}

/// Encode and ship one `SUBMITB` frame in the writer's negotiated
/// framing. Free function so the caller and the autobatch timer thread
/// share one code path under the writer lock.
fn ship_submitb(w: &mut ClientWriter, frame: &[TaskSpec]) -> Result<()> {
    let ClientWriter { stream, enc, binary } = w;
    if *binary {
        encode_submitb_bin(frame, enc)?;
        stream.write_all(enc)?;
    } else {
        let wire = encode_submitb(frame)?;
        stream.write_all(wire.as_bytes())?;
    }
    Ok(())
}

impl FalkonClient {
    /// Connect to a running [`FalkonTcpServer`] (legacy text framing).
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect falkon")?;
        stream.set_nodelay(true).ok();
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: Arc::new(Mutex::new(ClientWriter {
                stream,
                enc: Vec::new(),
                binary: false,
            })),
            binary: false,
            frame_buf: Vec::new(),
            pending: VecDeque::new(),
            submit_buf: None,
            timer: None,
        })
    }

    /// Connect and negotiate binary framing: send the [`BIN_MAGIC`]
    /// preamble, require the [`BIN_ACK`] reply. Fails (closed socket or
    /// unexpected reply) against a text-only peer.
    pub fn connect_binary(addr: impl std::net::ToSocketAddrs) -> Result<Self> {
        let mut c = Self::connect(addr)?;
        c.writer
            .lock()
            .unwrap()
            .stream
            .write_all(format!("{BIN_MAGIC}\n").as_bytes())?;
        let mut line = String::new();
        if c.reader.read_line(&mut line)? == 0 {
            bail!("server closed during binary negotiation (text-only peer?)");
        }
        if line.trim() != BIN_ACK {
            bail!("unexpected binary negotiation reply {:?}", line.trim());
        }
        c.binary = true;
        c.writer.lock().unwrap().binary = true;
        Ok(c)
    }

    /// Connect with binary framing if the server supports it, falling
    /// back to a fresh legacy text connection otherwise. This is the
    /// version-agnostic entry point: new clients against old servers
    /// degrade transparently.
    pub fn connect_preferring_binary(
        addr: impl std::net::ToSocketAddrs + Clone,
    ) -> Result<Self> {
        match Self::connect_binary(addr.clone()) {
            Ok(c) => Ok(c),
            Err(_) => Self::connect(addr),
        }
    }

    /// Whether this connection negotiated binary framing.
    pub fn is_binary(&self) -> bool {
        self.binary
    }

    /// Enable Nagle-style submit coalescing: buffered submissions cut
    /// into `SUBMITB` frames of up to `max_tasks` (clamped to the wire
    /// cap), or whenever the oldest buffered task is `max_age` old
    /// (checked on every client call; see
    /// [`FalkonClient::with_autobatch_timer`] for call-free flushes).
    pub fn with_autobatch(mut self, max_tasks: usize, max_age: Duration) -> Self {
        self.submit_buf = Some(Arc::new(SubmitBuf {
            buf: Mutex::new(FrameCoalescer::new(FramePolicy {
                max_tasks: max_tasks.clamp(1, MAX_FRAME_TASKS),
                max_age,
            })),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        }));
        self
    }

    /// [`FalkonClient::with_autobatch`] plus a timer thread: the age
    /// cut-off fires on the coalescer's own deadline, so a buffered
    /// task never waits on another client call to ship. The thread
    /// joins cleanly when the client drops.
    pub fn with_autobatch_timer(self, max_tasks: usize, max_age: Duration) -> Self {
        let mut client = self.with_autobatch(max_tasks, max_age);
        let shared = Arc::clone(client.submit_buf.as_ref().expect("just set"));
        let writer = Arc::clone(&client.writer);
        let h = std::thread::Builder::new()
            .name("falkon-client-autobatch".into())
            .spawn(move || autobatch_timer_loop(shared, writer))
            .expect("spawn autobatch timer");
        client.timer = Some(h);
        client
    }

    /// Buffer one submission behind the autobatch cut-off. Without
    /// [`FalkonClient::with_autobatch`], degrades to an immediate
    /// single-task frame. Malformed specs (whitespace in a wire word)
    /// are rejected *here*, before buffering — a bad task must fail
    /// its own submit call, not poison a whole frame at cut time
    /// (where the timer thread has no caller to report to).
    pub fn submit_buffered(&mut self, spec: TaskSpec) -> Result<()> {
        ensure_wire_word(&spec.executable, "executable")?;
        for a in &spec.args {
            ensure_wire_word(a, "arg")?;
        }
        let Some(shared) = self.submit_buf.as_ref() else {
            let frame = [spec];
            return self.write_submitb(&frame);
        };
        let now = Instant::now();
        let (frame, due) = {
            let mut buf = shared.buf.lock().unwrap();
            let frame = buf.push(spec, now);
            (frame, buf.due(now))
        };
        // Wake the timer thread so it re-arms on the new deadline.
        shared.cv.notify_one();
        if let Some(frame) = frame {
            return self.write_submitb(&frame);
        }
        if due {
            return self.flush();
        }
        Ok(())
    }

    /// Ship every buffered submission now (the escape hatch; also runs
    /// before any blocking read).
    pub fn flush(&mut self) -> Result<()> {
        let Some(shared) = self.submit_buf.as_ref() else {
            return Ok(());
        };
        loop {
            let frame = shared.buf.lock().unwrap().take_frame();
            match frame {
                Some(frame) => self.write_submitb(&frame)?,
                None => return Ok(()),
            }
        }
    }

    fn write_submitb(&self, frame: &[TaskSpec]) -> Result<()> {
        ship_submitb(&mut self.writer.lock().unwrap(), frame)
    }

    /// Fire a single submission without waiting (a legacy `SUBMIT` line
    /// in text mode; a one-task `SUBMITB` frame in binary mode, which
    /// has no single-task opcode by design).
    pub fn submit(&mut self, id: u64, executable: &str, args: &[&str]) -> Result<()> {
        if self.binary {
            let spec = TaskSpec {
                id,
                executable: executable.to_string(),
                args: args.iter().map(|s| s.to_string()).collect(),
            };
            return self.write_submitb(std::slice::from_ref(&spec));
        }
        let mut line = format!("SUBMIT {id} {executable}");
        for a in args {
            line.push(' ');
            line.push_str(a);
        }
        line.push('\n');
        self.writer.lock().unwrap().stream.write_all(line.as_bytes())?;
        Ok(())
    }

    /// Fire a whole batch as `SUBMITB` frames (one write and one
    /// server-side queue operation per frame) without waiting. Batches
    /// above [`MAX_FRAME_TASKS`] are split into maximal frames so no
    /// legal call can trip the server's frame cap.
    pub fn submit_batch(&mut self, tasks: &[TaskSpec]) -> Result<()> {
        for frame in tasks.chunks(MAX_FRAME_TASKS) {
            self.write_submitb(frame)?;
        }
        Ok(())
    }

    /// Read the next completion (results may arrive in any order, from
    /// `RESULT` lines or `DONEB` frames alike). Flushes any buffered
    /// submissions first so the read can't deadlock on them.
    pub fn next_result(&mut self) -> Result<RemoteResult> {
        if let Some(r) = self.pending.pop_front() {
            return Ok(r);
        }
        self.flush()?;
        if self.binary {
            loop {
                let Some(op) = read_bin_frame(&mut self.reader, &mut self.frame_buf)?
                else {
                    bail!("connection closed");
                };
                if op == OP_DONEB {
                    self.pending.extend(decode_doneb_bin(&self.frame_buf)?);
                }
                if let Some(r) = self.pending.pop_front() {
                    return Ok(r);
                }
            }
        }
        // One reused line buffer: this is the ack hot path (fig12 reads
        // tens of thousands of lines per run).
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                bail!("connection closed");
            }
            self.decode_ack_line(&line)?;
            if let Some(r) = self.pending.pop_front() {
                return Ok(r);
            }
        }
    }

    /// Decode one server line that may carry results (`RESULT` or a
    /// `DONEB` header) into `pending`; other lines are ignored.
    fn decode_ack_line(&mut self, line: &str) -> Result<()> {
        let trimmed = line.trim();
        if let Some(fields) = trimmed.strip_prefix("RESULT ") {
            self.pending.push_back(parse_status_fields(fields)?);
        } else if let Some(count) = trimmed.strip_prefix("DONEB ") {
            let n: usize = count.trim().parse().context("bad DONEB count")?;
            self.pending.extend(decode_doneb_body(n, &mut self.reader)?);
        }
        Ok(())
    }

    /// Convenience: submit one task and wait for that id.
    pub fn run(&mut self, id: u64, executable: &str, args: &[&str]) -> Result<RemoteResult> {
        self.submit(id, executable, args)?;
        loop {
            let r = self.next_result()?;
            if r.id == id {
                return Ok(r);
            }
        }
    }

    /// Query service stats: (submitted, completed, failed, queue length,
    /// live executors). Results arriving before the STATS reply are
    /// stashed for later [`FalkonClient::next_result`] calls, not
    /// dropped.
    pub fn stats(&mut self) -> Result<(u64, u64, u64, usize, usize)> {
        self.flush()?;
        if self.binary {
            {
                let mut w = self.writer.lock().unwrap();
                let ClientWriter { stream, enc, .. } = &mut *w;
                encode_stats_req_bin(enc);
                stream.write_all(enc)?;
            }
            loop {
                let Some(op) = read_bin_frame(&mut self.reader, &mut self.frame_buf)?
                else {
                    bail!("connection closed");
                };
                match op {
                    OP_STATS_REPLY => {
                        let s = decode_stats_reply_bin(&self.frame_buf)?;
                        return Ok((s[0], s[1], s[2], s[3] as usize, s[4] as usize));
                    }
                    OP_DONEB => {
                        self.pending.extend(decode_doneb_bin(&self.frame_buf)?);
                    }
                    _ => {}
                }
            }
        }
        self.writer.lock().unwrap().stream.write_all(b"STATS\n")?;
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                bail!("connection closed");
            }
            let parts: Vec<&str> = line.trim().split(' ').collect();
            if parts.first() == Some(&"STATS") && parts.len() == 6 {
                return Ok((
                    parts[1].parse()?,
                    parts[2].parse()?,
                    parts[3].parse()?,
                    parts[4].parse()?,
                    parts[5].parse()?,
                ));
            }
            self.decode_ack_line(&line)?;
        }
    }

    /// Pull a full live [`MetricsSnapshot`] from the service: the
    /// telemetry sibling of [`FalkonClient::stats`]. Binary framing
    /// only — a text connection has no scrape opcode. Results arriving
    /// before the reply are stashed, not dropped.
    pub fn scrape(&mut self) -> Result<MetricsSnapshot> {
        self.flush()?;
        if !self.binary {
            bail!("scrape requires binary framing (connect_preferring_binary)");
        }
        {
            let mut w = self.writer.lock().unwrap();
            let ClientWriter { stream, enc, .. } = &mut *w;
            encode_scrape_req_bin(enc);
            stream.write_all(enc)?;
        }
        loop {
            let Some(op) = read_bin_frame(&mut self.reader, &mut self.frame_buf)?
            else {
                bail!("connection closed");
            };
            match op {
                OP_SCRAPE_REPLY => {
                    return decode_scrape_reply_bin(&self.frame_buf);
                }
                OP_DONEB => {
                    self.pending.extend(decode_doneb_bin(&self.frame_buf)?);
                }
                _ => {}
            }
        }
    }
}

impl Drop for FalkonClient {
    fn drop(&mut self) {
        if let Some(shared) = self.submit_buf.as_ref() {
            // Store the flag while holding the buffer lock so the
            // timer thread is either before its shutdown check (and
            // will see the flag) or parked in the condvar (and gets
            // the notification) — no missed-wakeup window.
            let _guard = shared
                .buf
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.cv.notify_all();
        }
        if let Some(h) = self.timer.take() {
            let _ = h.join();
        }
    }
}

/// The autobatch timer thread: sleep until the coalescer's age
/// deadline, cut and ship the due frame, repeat. Mirrors the
/// scheduler's clustering flusher — the coalescer owns the cut-off,
/// this thread owns only the waiting.
///
/// Error semantics match the server's ack writer: a failed socket
/// write drops the frame silently and the caller discovers the broken
/// connection on its next read (specs are validated before buffering,
/// so encode itself cannot fail here). Writes are blocking — like
/// every TCP write in this endpoint — so a peer that stops reading
/// mid-frame can stall the timer (and a concurrent `drop` of the
/// client, which joins this thread) until the kernel buffer drains or
/// the connection dies.
fn autobatch_timer_loop(shared: Arc<SubmitBuf>, writer: Arc<Mutex<ClientWriter>>) {
    let mut buf = shared.buf.lock().unwrap_or_else(|e| e.into_inner());
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match buf.deadline() {
            None => {
                buf = shared.cv.wait(buf).unwrap_or_else(|e| e.into_inner());
            }
            Some(deadline) => {
                let now = Instant::now();
                if now >= deadline {
                    let frame = buf.take_frame();
                    drop(buf);
                    if let Some(frame) = frame {
                        if let Ok(mut w) = writer.lock() {
                            let _ = ship_submitb(&mut w, &frame);
                        }
                    }
                    buf = shared.buf.lock().unwrap_or_else(|e| e.into_inner());
                } else {
                    let (g, _) = shared
                        .cv
                        .wait_timeout(buf, deadline.saturating_duration_since(now))
                        .unwrap_or_else(|e| e.into_inner());
                    buf = g;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::falkon::service::{FalkonServiceConfig, RealDrpPolicy};
    use std::io::Cursor;
    use std::time::Duration;

    fn start_svc() -> (Arc<FalkonService>, FalkonTcpServer) {
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(2),
                executor_overhead: Duration::ZERO,
            },
            Arc::new(|t| {
                if t.executable == "fail" {
                    anyhow::bail!("requested failure")
                }
                Ok(())
            }),
        );
        let server = FalkonTcpServer::start(Arc::clone(&svc), "127.0.0.1:0").unwrap();
        (svc, server)
    }

    fn spec(id: u64, exe: &str, args: &[&str]) -> TaskSpec {
        TaskSpec {
            id,
            executable: exe.into(),
            args: args.iter().map(|s| s.to_string()).collect(),
        }
    }

    // -- pure frame round-trips ----------------------------------------

    #[test]
    fn submitb_frame_roundtrip() {
        let tasks = vec![
            spec(1, "convert", &["-i", "a.img", "-o", "b.img"]),
            spec(2, "sleep0", &[]),
            spec(99, "align", &["m12"]),
        ];
        let wire = encode_submitb(&tasks).unwrap();
        let mut lines = wire.lines();
        let header = lines.next().unwrap();
        assert_eq!(header, "SUBMITB 3");
        let body = wire.splitn(2, '\n').nth(1).unwrap();
        let decoded = decode_submitb_body(3, &mut Cursor::new(body)).unwrap();
        assert_eq!(decoded, tasks);
    }

    #[test]
    fn doneb_frame_roundtrip() {
        let results = vec![
            RemoteResult { id: 7, ok: true, exec_us: 120, wait_us: 3, error: String::new() },
            RemoteResult {
                id: 8,
                ok: false,
                exec_us: 0,
                wait_us: 11,
                error: "boom with spaces".into(),
            },
        ];
        let wire = encode_doneb(&results);
        assert!(wire.starts_with("DONEB 2\n"));
        let body = wire.splitn(2, '\n').nth(1).unwrap();
        let decoded = decode_doneb_body(2, &mut Cursor::new(body)).unwrap();
        assert_eq!(decoded, results);
    }

    #[test]
    fn truncated_submitb_frame_is_an_error() {
        let tasks: Vec<TaskSpec> = (0..4).map(|i| spec(i, "x", &[])).collect();
        let wire = encode_submitb(&tasks).unwrap();
        let body = wire.splitn(2, '\n').nth(1).unwrap();
        // Keep only the first two task lines of four.
        let cut: String = body.lines().take(2).map(|l| format!("{l}\n")).collect();
        let err = decode_submitb_body(4, &mut Cursor::new(cut)).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn truncated_doneb_frame_is_an_error() {
        let err = decode_doneb_body(3, &mut Cursor::new("1 ok 5 5 \n")).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn oversized_frame_counts_are_rejected() {
        let e = decode_submitb_body(MAX_FRAME_TASKS + 1, &mut Cursor::new("")).unwrap_err();
        assert!(format!("{e:#}").contains("cap"), "{e:#}");
        let e = decode_doneb_body(MAX_FRAME_TASKS + 1, &mut Cursor::new("")).unwrap_err();
        assert!(format!("{e:#}").contains("cap"), "{e:#}");
    }

    #[test]
    fn malformed_task_line_is_an_error() {
        // Missing executable.
        assert!(decode_submitb_body(1, &mut Cursor::new("42\n")).is_err());
        // Non-numeric id.
        assert!(decode_submitb_body(1, &mut Cursor::new("nope x\n")).is_err());
    }

    #[test]
    fn encode_rejects_whitespace_in_wire_words() {
        // An embedded space would split into extra wire args...
        assert!(encode_submitb(&[spec(1, "x", &["a b"])]).is_err());
        // ...and an embedded newline would desynchronize the frame.
        assert!(encode_submitb(&[spec(1, "x\n2 y", &[])]).is_err());
        assert!(encode_submitb(&[spec(1, "", &[])]).is_err());
        assert!(encode_submitb(&[spec(1, "ok", &["fine"])]).is_ok());
    }

    // -- live TCP ------------------------------------------------------

    #[test]
    fn tcp_submit_roundtrip() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        let r = client.run(1, "sleep0", &[]).unwrap();
        assert!(r.ok);
        assert_eq!(r.id, 1);
    }

    #[test]
    fn tcp_reports_failures() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        let r = client.run(2, "fail", &[]).unwrap();
        assert!(!r.ok);
        assert!(r.error.contains("requested failure"));
    }

    #[test]
    fn tcp_pipeline_many_submissions() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        let n = 200;
        for i in 0..n {
            client.submit(i, "sleep0", &[]).unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..n {
            let r = client.next_result().unwrap();
            assert!(r.ok);
            seen.insert(r.id);
        }
        assert_eq!(seen.len(), n as usize);
    }

    #[test]
    fn tcp_batched_frames_roundtrip_mixed_outcomes() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        let tasks: Vec<TaskSpec> = (0..120u64)
            .map(|i| spec(i, if i % 10 == 0 { "fail" } else { "sleep0" }, &[]))
            .collect();
        client.submit_batch(&tasks).unwrap();
        let mut seen = std::collections::HashMap::new();
        for _ in 0..tasks.len() {
            let r = client.next_result().unwrap();
            seen.insert(r.id, r.ok);
        }
        assert_eq!(seen.len(), tasks.len(), "every frame task acked once");
        for i in 0..120u64 {
            assert_eq!(seen[&i], i % 10 != 0, "task {i}");
        }
    }

    #[test]
    fn tcp_mixed_legacy_and_framed_submissions() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        client.submit(1000, "sleep0", &[]).unwrap();
        client
            .submit_batch(&(0..50u64).map(|i| spec(i, "sleep0", &[])).collect::<Vec<_>>())
            .unwrap();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..51 {
            let r = client.next_result().unwrap();
            assert!(r.ok);
            seen.insert(r.id);
        }
        assert!(seen.contains(&1000), "legacy RESULT ack decoded");
        assert_eq!(seen.len(), 51);
    }

    #[test]
    fn autobatch_coalesces_singles_into_frames() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr())
            .unwrap()
            .with_autobatch(8, Duration::from_secs(60));
        // 20 buffered singles with a 60 s age threshold: only the batch
        // cut-off fires, shipping two full frames; 4 tasks stay
        // buffered until the explicit flush.
        for i in 0..20u64 {
            client.submit_buffered(spec(i, "sleep0", &[])).unwrap();
        }
        assert_eq!(
            client.submit_buf.as_ref().unwrap().buf.lock().unwrap().len(),
            4,
            "two full frames shipped, remainder still buffered"
        );
        client.flush().unwrap();
        assert!(client
            .submit_buf
            .as_ref()
            .unwrap()
            .buf
            .lock()
            .unwrap()
            .is_empty());
        let mut seen = std::collections::HashSet::new();
        for _ in 0..20 {
            let r = client.next_result().unwrap();
            assert!(r.ok);
            seen.insert(r.id);
        }
        assert_eq!(seen.len(), 20, "every buffered task acked once");
    }

    #[test]
    fn autobatch_zero_age_ships_immediately() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr())
            .unwrap()
            .with_autobatch(100, Duration::ZERO);
        // Age threshold zero: the push itself is already due, so the
        // task ships without filling the batch and without flush().
        client.submit_buffered(spec(1, "sleep0", &[])).unwrap();
        let r = client.next_result().unwrap();
        assert!(r.ok);
        assert_eq!(r.id, 1);
    }

    #[test]
    fn submit_buffered_rejects_malformed_specs_before_buffering() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr())
            .unwrap()
            .with_autobatch(8, Duration::from_secs(60));
        // A whitespace executable must fail the submit call itself —
        // never reach the buffer, where it would poison a whole frame
        // at cut time with no caller to report to.
        assert!(client.submit_buffered(spec(1, "bad exe", &[])).is_err());
        assert!(client
            .submit_buf
            .as_ref()
            .unwrap()
            .buf
            .lock()
            .unwrap()
            .is_empty());
    }

    #[test]
    fn autobatch_timer_flushes_aged_frames_without_client_calls() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr())
            .unwrap()
            .with_autobatch_timer(100, Duration::from_millis(30));
        client.submit_buffered(spec(5, "sleep0", &[])).unwrap();
        // No further client calls: the timer thread alone must cut the
        // frame once the 30 ms age threshold passes.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let empty = client
                .submit_buf
                .as_ref()
                .unwrap()
                .buf
                .lock()
                .unwrap()
                .is_empty();
            if empty {
                break;
            }
            assert!(Instant::now() < deadline, "timer never flushed the frame");
            std::thread::sleep(Duration::from_millis(5));
        }
        let r = client.next_result().unwrap();
        assert!(r.ok);
        assert_eq!(r.id, 5);
    }

    #[test]
    fn autobatch_timer_shutdown_joins_cleanly() {
        let (_svc, server) = start_svc();
        let client = FalkonClient::connect(server.addr())
            .unwrap()
            .with_autobatch_timer(100, Duration::from_secs(60));
        // Drop must interrupt the 60 s age wait and join the timer
        // thread without hanging.
        drop(client);
    }

    #[test]
    fn next_result_flushes_buffered_submits() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr())
            .unwrap()
            .with_autobatch(100, Duration::from_secs(60));
        // Neither cut-off fires; the blocking read must flush or it
        // would deadlock waiting for a task the server never saw.
        client.submit_buffered(spec(9, "sleep0", &[])).unwrap();
        let r = client.next_result().unwrap();
        assert_eq!(r.id, 9);
    }

    #[test]
    fn tcp_stats_query() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect(server.addr()).unwrap();
        client.run(1, "sleep0", &[]).unwrap();
        let (submitted, completed, failed, _q, execs) = client.stats().unwrap();
        assert_eq!(submitted, 1);
        assert_eq!(completed, 1);
        assert_eq!(failed, 0);
        assert_eq!(execs, 2);
    }

    // -- binary framing (pure) -----------------------------------------

    /// Strip the `[u32 len][u8 opcode]` header of one encoded frame,
    /// checking the length prefix and opcode on the way.
    fn bin_payload(buf: &[u8], want_op: u8) -> &[u8] {
        let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
        assert_eq!(len, buf.len() - 4, "length prefix covers opcode + payload");
        assert_eq!(buf[4], want_op);
        &buf[5..]
    }

    #[test]
    fn submitb_bin_roundtrip() {
        let tasks = vec![
            spec(1, "convert", &["-i", "a.img", "-o", "b.img"]),
            spec(2, "sleep0", &[]),
            spec(u64::MAX, "align", &["m12"]),
        ];
        let mut buf = Vec::new();
        encode_submitb_bin(&tasks, &mut buf).unwrap();
        let decoded = decode_submitb_bin(bin_payload(&buf, OP_SUBMITB)).unwrap();
        assert_eq!(decoded, tasks);
    }

    #[test]
    fn submitb_bin_iter_reuses_one_arg_spine() {
        let tasks = vec![spec(3, "a", &["x", "y"]), spec(4, "b", &["z"])];
        let mut buf = Vec::new();
        encode_submitb_bin(&tasks, &mut buf).unwrap();
        let payload = bin_payload(&buf, OP_SUBMITB);
        let mut iter = SubmitbBinIter::parse(payload).unwrap();
        assert_eq!(iter.remaining(), 2);
        let mut args = Vec::new();
        let (id, exe) = iter.next_task(&mut args).unwrap().unwrap();
        assert_eq!((id, exe), (3, "a"));
        assert_eq!(args, ["x", "y"]);
        let (id, exe) = iter.next_task(&mut args).unwrap().unwrap();
        assert_eq!((id, exe), (4, "b"));
        assert_eq!(args, ["z"], "spine cleared and refilled per task");
        assert!(iter.next_task(&mut args).unwrap().is_none());
    }

    #[test]
    fn doneb_bin_roundtrip_flattens_newlines_like_text() {
        let results = vec![
            RemoteResult { id: 7, ok: true, exec_us: 120, wait_us: 3, error: String::new() },
            RemoteResult {
                id: 8,
                ok: false,
                exec_us: 0,
                wait_us: 11,
                error: "boom\nwith newline".into(),
            },
        ];
        let mut buf = Vec::new();
        encode_doneb_bin(&results, &mut buf).unwrap();
        let decoded = decode_doneb_bin(bin_payload(&buf, OP_DONEB)).unwrap();
        assert_eq!(decoded[0], results[0]);
        assert_eq!(decoded[1].error, "boom with newline", "newline flattened");
        // Parity with the text grammar's flattening.
        let text = encode_doneb(&results);
        let body = text.splitn(2, '\n').nth(1).unwrap();
        let text_decoded = decode_doneb_body(2, &mut Cursor::new(body)).unwrap();
        assert_eq!(decoded, text_decoded);
    }

    #[test]
    fn stats_bin_roundtrip() {
        let stats = [1u64, 2, 3, 4, 5];
        let mut buf = Vec::new();
        encode_stats_reply_bin(&stats, &mut buf);
        let got = decode_stats_reply_bin(bin_payload(&buf, OP_STATS_REPLY)).unwrap();
        assert_eq!(got, stats);
        encode_stats_req_bin(&mut buf);
        assert!(bin_payload(&buf, OP_STATS).is_empty());
    }

    fn sample_snapshot() -> MetricsSnapshot {
        use crate::telemetry::counters::LocalCounters;
        let mut local = LocalCounters::new();
        local.add(Counter::TasksSubmitted, 120);
        local.add(Counter::FramesEncoded, 9);
        for v in [5u64, 80, 1300] {
            local.observe(Hist::DispatchWaitUs, v);
        }
        MetricsSnapshot::new(
            ServiceSection {
                uptime_us: 1_234_567,
                submitted: 120,
                completed: 118,
                failed: 2,
                queue_len: 0,
                peak_queue: 64,
                live_executors: 2,
                peak_executors: 4,
                busy_us: 99_000,
            },
            local.snapshot(),
        )
    }

    #[test]
    fn scrape_bin_roundtrip() {
        let snap = sample_snapshot();
        let mut buf = Vec::new();
        encode_scrape_reply_bin(&snap, &mut buf).unwrap();
        let got = decode_scrape_reply_bin(bin_payload(&buf, OP_SCRAPE_REPLY)).unwrap();
        assert_eq!(got, snap);
        encode_scrape_req_bin(&mut buf);
        assert!(bin_payload(&buf, OP_SCRAPE).is_empty());
    }

    #[test]
    fn truncated_scrape_reply_is_an_error_at_every_cut() {
        let mut buf = Vec::new();
        encode_scrape_reply_bin(&sample_snapshot(), &mut buf).unwrap();
        let payload = bin_payload(&buf, OP_SCRAPE_REPLY);
        for cut in 0..payload.len() {
            assert!(
                decode_scrape_reply_bin(&payload[..cut]).is_err(),
                "cut at {cut} must error, not panic or succeed"
            );
        }
    }

    #[test]
    fn scrape_decoder_skips_unknown_sections() {
        // A future server prepends a section id 200: an old decoder
        // must skip it by length and still read the known sections.
        let snap = sample_snapshot();
        let mut buf = Vec::new();
        encode_scrape_reply_bin(&snap, &mut buf).unwrap();
        let payload = bin_payload(&buf, OP_SCRAPE_REPLY);
        let mut patched = payload[..3].to_vec();
        patched[2] = payload[2] + 1; // n_sections
        patched.extend_from_slice(&[200u8]);
        patched.extend_from_slice(&3u32.to_le_bytes());
        patched.extend_from_slice(&[1, 2, 3]);
        patched.extend_from_slice(&payload[3..]);
        let got = decode_scrape_reply_bin(&patched).unwrap();
        assert_eq!(got, snap);
    }

    #[test]
    fn truncated_bin_payload_is_an_error_at_every_cut() {
        let tasks = vec![spec(1, "convert", &["-i", "a.img"])];
        let mut buf = Vec::new();
        encode_submitb_bin(&tasks, &mut buf).unwrap();
        let payload = bin_payload(&buf, OP_SUBMITB);
        for cut in 0..payload.len() {
            assert!(
                decode_submitb_bin(&payload[..cut]).is_err(),
                "cut at {cut} must error, not panic or succeed"
            );
        }
    }

    #[test]
    fn trailing_bytes_after_bin_frame_are_an_error() {
        let mut buf = Vec::new();
        encode_submitb_bin(&[spec(1, "x", &[])], &mut buf).unwrap();
        let mut payload = bin_payload(&buf, OP_SUBMITB).to_vec();
        payload.push(0xAB);
        let err = decode_submitb_bin(&payload).unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
    }

    #[test]
    fn bin_encode_rejects_whitespace_like_text() {
        let mut buf = Vec::new();
        assert!(encode_submitb_bin(&[spec(1, "x", &["a b"])], &mut buf).is_err());
        assert!(encode_submitb_bin(&[spec(1, "x\ny", &[])], &mut buf).is_err());
        assert!(encode_submitb_bin(&[spec(1, "", &[])], &mut buf).is_err());
        assert!(encode_submitb_bin(&[spec(1, "ok", &["fine"])], &mut buf).is_ok());
    }

    #[test]
    fn read_bin_frame_distinguishes_clean_close_from_truncation() {
        let mut frame = Vec::new();
        encode_submitb_bin(&[spec(1, "x", &[])], &mut frame).unwrap();
        // Clean close: EOF exactly at a frame boundary.
        let mut payload = Vec::new();
        let mut r = Cursor::new(frame.clone());
        assert_eq!(read_bin_frame(&mut r, &mut payload).unwrap(), Some(OP_SUBMITB));
        assert!(read_bin_frame(&mut r, &mut payload).unwrap().is_none());
        // Truncation: EOF mid-frame is an error at every cut point.
        for cut in 1..frame.len() {
            let mut r = Cursor::new(frame[..cut].to_vec());
            assert!(
                read_bin_frame(&mut r, &mut payload).is_err(),
                "cut at {cut} must error"
            );
        }
        // Hostile length prefix.
        let mut hostile = ((MAX_BIN_FRAME_BYTES + 1) as u32).to_le_bytes().to_vec();
        hostile.push(OP_SUBMITB);
        let err = read_bin_frame(&mut Cursor::new(hostile), &mut payload).unwrap_err();
        assert!(format!("{err:#}").contains("cap"), "{err:#}");
    }

    // -- binary framing (live TCP) -------------------------------------

    #[test]
    fn tcp_binary_roundtrip() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect_binary(server.addr()).unwrap();
        assert!(client.is_binary());
        let r = client.run(1, "sleep0", &[]).unwrap();
        assert!(r.ok);
        assert_eq!(r.id, 1);
        let r = client.run(2, "fail", &[]).unwrap();
        assert!(!r.ok);
        assert!(r.error.contains("requested failure"));
    }

    #[test]
    fn tcp_binary_batch_and_stats() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect_preferring_binary(server.addr()).unwrap();
        assert!(client.is_binary(), "our own server negotiates binary");
        let tasks: Vec<TaskSpec> = (0..120u64)
            .map(|i| spec(i, if i % 10 == 0 { "fail" } else { "sleep0" }, &["arg1"]))
            .collect();
        client.submit_batch(&tasks).unwrap();
        let mut seen = std::collections::HashMap::new();
        for _ in 0..tasks.len() {
            let r = client.next_result().unwrap();
            seen.insert(r.id, r.ok);
        }
        assert_eq!(seen.len(), tasks.len());
        for i in 0..120u64 {
            assert_eq!(seen[&i], i % 10 != 0, "task {i}");
        }
        let (submitted, completed, failed, _q, execs) = client.stats().unwrap();
        assert_eq!(submitted, 120);
        assert_eq!(completed, 120);
        assert_eq!(failed, 12);
        assert_eq!(execs, 2);
    }

    #[test]
    fn tcp_scrape_returns_live_snapshot() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect_binary(server.addr()).unwrap();
        let tasks: Vec<TaskSpec> =
            (0..40u64).map(|i| spec(i, "sleep0", &[])).collect();
        client.submit_batch(&tasks).unwrap();
        for _ in 0..tasks.len() {
            assert!(client.next_result().unwrap().ok);
        }
        let snap = client.scrape().unwrap();
        assert_eq!(snap.version, crate::telemetry::SNAPSHOT_VERSION);
        assert_eq!(snap.service.submitted, 40);
        assert_eq!(snap.service.completed, 40);
        assert_eq!(snap.service.failed, 0);
        assert_eq!(snap.service.live_executors, 2);
        // The counter registry is process-global (floors, not exacts:
        // sibling tests record concurrently).
        assert!(snap.counters.get("tasks_submitted") >= 40);
        assert!(snap.counters.get("frames_decoded") >= 1);
        assert!(snap.counters.hist_count("dispatch_wait_us") >= 40);
        // A text connection has no scrape opcode.
        let mut text = FalkonClient::connect(server.addr()).unwrap();
        assert!(text.scrape().is_err());
    }

    #[test]
    fn tcp_mixed_text_and_binary_clients_share_one_server() {
        let (_svc, server) = start_svc();
        let mut text = FalkonClient::connect(server.addr()).unwrap();
        let mut bin = FalkonClient::connect_binary(server.addr()).unwrap();
        text.submit_batch(&(0..30u64).map(|i| spec(i, "sleep0", &[])).collect::<Vec<_>>())
            .unwrap();
        bin.submit_batch(
            &(100..130u64).map(|i| spec(i, "sleep0", &[])).collect::<Vec<_>>(),
        )
        .unwrap();
        let mut text_ids = std::collections::HashSet::new();
        let mut bin_ids = std::collections::HashSet::new();
        for _ in 0..30 {
            text_ids.insert(text.next_result().unwrap().id);
            bin_ids.insert(bin.next_result().unwrap().id);
        }
        assert!(text_ids.iter().all(|&i| i < 30), "acks routed per connection");
        assert!(bin_ids.iter().all(|&i| (100..130).contains(&i)));
        assert_eq!((text_ids.len(), bin_ids.len()), (30, 30));
    }

    #[test]
    fn tcp_binary_autobatch_roundtrip() {
        let (_svc, server) = start_svc();
        let mut client = FalkonClient::connect_binary(server.addr())
            .unwrap()
            .with_autobatch_timer(8, Duration::from_millis(10));
        for i in 0..20u64 {
            client.submit_buffered(spec(i, "sleep0", &[])).unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..20 {
            let r = client.next_result().unwrap();
            assert!(r.ok);
            seen.insert(r.id);
        }
        assert_eq!(seen.len(), 20);
    }

    #[test]
    fn garbage_preamble_closes_the_connection() {
        let (_svc, server) = start_svc();
        let mut raw = TcpStream::connect(server.addr()).unwrap();
        raw.write_all(b"XYZZY plugh\n").unwrap();
        let mut buf = [0u8; 16];
        let n = std::io::Read::read(&mut raw, &mut buf).unwrap();
        assert_eq!(n, 0, "server closes on a garbage request, no reply bytes");
    }

    #[test]
    fn preferring_binary_falls_back_against_text_only_server() {
        // A hand-rolled legacy server: treats the magic preamble as a
        // bad request (closes), then speaks minimal text protocol on
        // the retry connection.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (s1, _) = listener.accept().unwrap();
            let mut r = BufReader::new(s1);
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            assert_eq!(line.trim(), BIN_MAGIC);
            drop(r); // legacy server: bad request, close
            let (s2, _) = listener.accept().unwrap();
            let mut r = BufReader::new(s2.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let id: u64 = line.trim().split(' ').nth(1).unwrap().parse().unwrap();
            let mut w = s2;
            w.write_all(format!("RESULT {id} ok 1 1 \n").as_bytes()).unwrap();
        });
        let mut client = FalkonClient::connect_preferring_binary(addr).unwrap();
        assert!(!client.is_binary(), "fell back to text");
        let r = client.run(77, "sleep0", &[]).unwrap();
        assert!(r.ok);
        assert_eq!(r.id, 77);
        h.join().unwrap();
    }
}
