//! The Falkon execution service (real clock).
//!
//! Architecture (paper Figure 5): clients submit tasks to the service
//! queue; the streamlined dispatcher hands each task to an idle executor
//! (two logical message exchanges per dispatch: task out, result back);
//! DRP watches the queue and grows/shrinks the executor pool, acquiring
//! resources through a (simulated-latency) LRM allocation call and
//! releasing executors that stay idle past the idle timeout.
//!
//! Implementation notes: executors are pull-based worker threads over a
//! [`ShardedQueue`] — the pop *is* the dispatch message, the completion
//! callback is the notification message. The dispatch core is built for
//! multi-core throughput:
//!
//! - the service queue is sharded (per-shard lock + condvar) with work
//!   stealing, so submitters and executors never serialize on one mutex;
//! - [`FalkonService::submit_batch`] / [`FalkonService::submit_bundle`]
//!   amortize one lock acquisition and one targeted wakeup over a whole
//!   bundle, and bundle completions aggregate with a single allocation;
//! - executors pop tasks in batches into a reused buffer (no allocation
//!   on the hot path) and wakeups are `notify_one`-targeted per shard —
//!   idle executors do not thundering-herd on every submit.
//!
//! The paper's 487 tasks/s corresponds to ~2 ms of dispatcher work per
//! task; this dispatcher's budget is single-digit microseconds (see
//! benches/falkon_micro.rs, which records `BENCH_dispatch.json`).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::interner::Sym;
use crate::policy::{DrpConfig, DrpController};
use crate::providers::{AppRunner, AppTask, BundleDone, TaskResult};
use crate::telemetry::counters::{self, Counter, Hist};
use crate::telemetry::spans::{self, SpanHandle, Stage};
use crate::telemetry::{MetricsSnapshot, ServiceSection};

use super::queue::ShardedQueue;

use super::queue::{DISPATCH_BATCH, MAX_SHARDS};

/// Dynamic resource provisioning policy (real clock): the timing knobs
/// live here; the sizing arithmetic (queued → desired count, chunking,
/// the deregistration floor) is [`crate::policy::DrpController`],
/// shared with the simulator's [`crate::sim::DrpPolicy`].
#[derive(Debug, Clone)]
pub struct RealDrpPolicy {
    pub min_executors: usize,
    pub max_executors: usize,
    /// Target one executor per this many queued tasks.
    pub tasks_per_executor: usize,
    /// Simulated allocation latency (GRAM4+PBS round trip). Zero for
    /// pure-throughput benchmarks.
    pub allocation_delay: Duration,
    /// Deregister executors idle this long (Duration::ZERO = never).
    pub idle_timeout: Duration,
    /// DRP evaluation period.
    pub check_interval: Duration,
}

impl RealDrpPolicy {
    /// The clock-free sizing controller for this policy. The real
    /// service allocates executors one at a time (threads, not node
    /// chunks), so `chunk` is 1.
    pub fn controller(&self) -> DrpController {
        DrpController::new(DrpConfig {
            min_executors: self.min_executors,
            max_executors: self.max_executors,
            tasks_per_executor: self.tasks_per_executor,
            chunk: 1,
        })
    }

    /// A fixed-size pool: provisioned once, never shrinks.
    pub fn static_pool(n: usize) -> Self {
        Self {
            min_executors: n,
            max_executors: n,
            tasks_per_executor: 1,
            allocation_delay: Duration::ZERO,
            idle_timeout: Duration::ZERO,
            check_interval: Duration::from_millis(50),
        }
    }

    /// On-demand provisioning between bounds.
    pub fn dynamic(min: usize, max: usize) -> Self {
        Self {
            min_executors: min,
            max_executors: max,
            tasks_per_executor: 1,
            allocation_delay: Duration::ZERO,
            idle_timeout: Duration::from_millis(500),
            check_interval: Duration::from_millis(20),
        }
    }
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct FalkonServiceConfig {
    pub drp: RealDrpPolicy,
    /// Per-task executor-side overhead (sandbox setup simulation); zero
    /// for raw dispatch benchmarks.
    pub executor_overhead: Duration,
}

impl Default for FalkonServiceConfig {
    fn default() -> Self {
        Self {
            drp: RealDrpPolicy::static_pool(4),
            executor_overhead: Duration::ZERO,
        }
    }
}

/// Aggregate service statistics (atomically maintained; readable while
/// the service runs).
#[derive(Debug, Default)]
pub struct ServiceStats {
    /// Tasks accepted by the service (all submit paths).
    pub submitted: AtomicU64,
    /// Tasks that finished successfully.
    pub completed: AtomicU64,
    /// Tasks that finished with an error.
    pub failed: AtomicU64,
    /// High-water mark of the service queue length.
    pub peak_queue: AtomicUsize,
    /// High-water mark of the live executor count.
    pub peak_executors: AtomicUsize,
    /// Total executor busy time (task execution only) in microseconds.
    pub busy_us: AtomicU64,
}

/// Per-task completion callback (the canonical alias lives in
/// [`crate::providers`]; re-exported here because the service API is
/// task-granular).
pub use crate::providers::TaskDone;

/// Cap on pooled arg-vector spines retained between tasks. Beyond this
/// the spines are simply dropped — the pool bounds memory, it does not
/// guarantee reuse.
const ARG_POOL_CAP: usize = 1024;

/// Recycles task-arg `Vec<String>` spines between the protocol decode
/// path and the executor handoff: a decoded task takes a spine from the
/// pool, the executor returns it (cleared) just before delivering the
/// result so the pool is warm for any submit the callback triggers.
/// The `String` elements themselves are dropped with the task — the
/// pool elides the per-task *vector* allocation, which is the part the
/// submit hot path pays even for arg-less tasks.
#[derive(Default)]
struct ArgPool {
    free: Mutex<Vec<Vec<String>>>,
}

impl ArgPool {
    fn take(&self) -> Vec<String> {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    fn put(&self, mut v: Vec<String>) {
        if v.capacity() == 0 {
            return;
        }
        v.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() < ARG_POOL_CAP {
            free.push(v);
        }
    }
}

/// Bundle-completion aggregation state: one allocation per bundle
/// instead of one boxed closure + shared mutex hop per task.
struct BundleAgg {
    results: Mutex<Vec<Option<TaskResult>>>,
    remaining: AtomicUsize,
    done: Mutex<Option<BundleDone>>,
}

impl BundleAgg {
    fn deliver(&self, idx: usize, r: TaskResult) {
        self.results.lock().unwrap()[idx] = Some(r);
        if self.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
            let results: Vec<TaskResult> = self
                .results
                .lock()
                .unwrap()
                .drain(..)
                .map(|r| r.expect("all bundle slots filled"))
                .collect();
            let done = self.done.lock().unwrap().take();
            if let Some(done) = done {
                done(results);
            }
        }
    }
}

/// How a queued task reports completion.
enum Completion {
    Single(TaskDone),
    Bundle { agg: Arc<BundleAgg>, idx: usize },
}

impl Completion {
    fn deliver(self, r: TaskResult) {
        match self {
            Completion::Single(done) => done(r),
            Completion::Bundle { agg, idx } => agg.deliver(idx, r),
        }
    }
}

struct Queued {
    task: AppTask,
    completion: Completion,
    enqueued: Instant,
    /// Lifecycle span handle, built (label interned) once at submit.
    /// `None` whenever global span recording is off — the executor's
    /// per-stage record sites then cost a single `Option` check.
    span: Option<SpanHandle>,
}

/// Build the task's lifecycle span and record its `Queued` stage.
/// Returns `None` (skipping the intern entirely) when spans are off.
fn queued_span(task: &AppTask) -> Option<SpanHandle> {
    if !spans::enabled() {
        return None;
    }
    let h = SpanHandle::new(task.id, Sym::intern(&task.executable));
    spans::record(h.event(Stage::Queued, spans::real_now_us()));
    Some(h)
}

struct Inner {
    cfg: FalkonServiceConfig,
    runner: AppRunner,
    queue: ShardedQueue<Queued>,
    live: AtomicUsize,
    next_exec_id: AtomicU64,
    stats: ServiceStats,
    arg_pool: ArgPool,
    started: Instant,
}

/// The Falkon service handle.
pub struct FalkonService {
    inner: Arc<Inner>,
    drp_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl FalkonService {
    /// Start the service with the given app runner.
    pub fn start(cfg: FalkonServiceConfig, runner: AppRunner) -> Arc<Self> {
        let nshards = cfg.drp.max_executors.clamp(1, MAX_SHARDS);
        let inner = Arc::new(Inner {
            cfg: cfg.clone(),
            runner,
            queue: ShardedQueue::new(nshards),
            live: AtomicUsize::new(0),
            next_exec_id: AtomicU64::new(0),
            stats: ServiceStats::default(),
            arg_pool: ArgPool::default(),
            started: Instant::now(),
        });
        // Bootstrap the minimum pool.
        for _ in 0..cfg.drp.min_executors {
            spawn_executor(&inner);
        }
        let svc = Arc::new(Self { inner, drp_thread: Mutex::new(None) });
        // DRP manager thread.
        let inner2 = Arc::clone(&svc.inner);
        let h = std::thread::Builder::new()
            .name("falkon-drp".into())
            .spawn(move || drp_loop(inner2))
            .expect("spawn drp");
        *svc.drp_thread.lock().unwrap() = Some(h);
        svc
    }

    /// Mirror the queue's exact high-water mark (maintained at push
    /// time) into the stats gauge with a monotonic CAS-max.
    fn note_queue_peak(&self) {
        let peak = self.inner.queue.peak();
        let gauge = &self.inner.stats.peak_queue;
        // ord: monotone max over a gauge; no payload rides on this cell
        let mut cur = gauge.load(Ordering::Relaxed);
        while peak > cur {
            match gauge.compare_exchange_weak(
                cur,
                peak,
                // ord: monotone max over a gauge; publishes no payload
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }

    /// Submit one task.
    pub fn submit(&self, task: AppTask, done: TaskDone) {
        let inner = &self.inner;
        // ord: commutative tally; readers take a racy snapshot
        inner.stats.submitted.fetch_add(1, Ordering::Relaxed);
        counters::incr(Counter::TasksSubmitted);
        let span = queued_span(&task);
        inner.queue.push(Queued {
            task,
            completion: Completion::Single(done),
            enqueued: Instant::now(),
            span,
        });
        self.note_queue_peak();
    }

    /// Submit a batch of independently-completing tasks: one shard lock
    /// and one wakeup per shard for the whole batch.
    pub fn submit_batch(&self, batch: Vec<(AppTask, TaskDone)>) {
        if batch.is_empty() {
            return;
        }
        let inner = &self.inner;
        inner
            .stats
            .submitted
            // ord: commutative tally; readers take a racy snapshot
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        counters::add(Counter::TasksSubmitted, batch.len() as u64);
        let now = Instant::now();
        let items: Vec<Queued> = batch
            .into_iter()
            .map(|(task, done)| {
                let span = queued_span(&task);
                Queued {
                    task,
                    completion: Completion::Single(done),
                    enqueued: now,
                    span,
                }
            })
            .collect();
        inner.queue.push_batch(items);
        self.note_queue_peak();
    }

    /// Submit a bundle whose results are delivered together, in order,
    /// through a single callback (the provider-facing batched path).
    pub fn submit_bundle(&self, tasks: Vec<AppTask>, done: BundleDone) {
        let n = tasks.len();
        if n == 0 {
            done(Vec::new());
            return;
        }
        let inner = &self.inner;
        // ord: commutative tally; readers take a racy snapshot
        inner.stats.submitted.fetch_add(n as u64, Ordering::Relaxed);
        counters::add(Counter::TasksSubmitted, n as u64);
        let agg = Arc::new(BundleAgg {
            results: Mutex::new((0..n).map(|_| None).collect()),
            remaining: AtomicUsize::new(n),
            done: Mutex::new(Some(done)),
        });
        let now = Instant::now();
        let items: Vec<Queued> = tasks
            .into_iter()
            .enumerate()
            .map(|(idx, task)| {
                let span = queued_span(&task);
                Queued {
                    task,
                    completion: Completion::Bundle { agg: Arc::clone(&agg), idx },
                    enqueued: now,
                    span,
                }
            })
            .collect();
        inner.queue.push_batch(items);
        self.note_queue_peak();
    }

    /// Submit and block for the result (client convenience).
    pub fn submit_wait(&self, task: AppTask) -> TaskResult {
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(task, Box::new(move |r| {
            let _ = tx.send(r);
        }));
        rx.recv().expect("service dropped")
    }

    /// Take a pooled task-arg spine (for callers that build [`AppTask`]s
    /// on a hot path, e.g. the binary protocol decoder). The executor
    /// returns spines to the pool after delivering results; pairing is
    /// optional — unpooled vectors work, pooled ones skip an allocation.
    pub fn arg_vec(&self) -> Vec<String> {
        self.inner.arg_pool.take()
    }

    /// Return an arg spine to the pool (cleared; `String` elements are
    /// dropped).
    pub fn recycle_args(&self, v: Vec<String>) {
        self.inner.arg_pool.put(v);
    }

    /// Live aggregate counters (lock-free reads).
    pub fn stats(&self) -> &ServiceStats {
        &self.inner.stats
    }

    /// Current service-queue depth (lock-free read).
    pub fn queue_len(&self) -> usize {
        self.inner.queue.len()
    }

    /// Registered executors currently alive.
    pub fn live_executors(&self) -> usize {
        self.inner.live.load(Ordering::SeqCst)
    }

    /// A full live metric snapshot: the service gauges plus the merged
    /// process-global counter/histogram registry. This is what the
    /// binary `OP_SCRAPE` protocol ships to `FalkonClient::scrape()`.
    pub fn scrape_snapshot(&self) -> MetricsSnapshot {
        self.note_queue_peak();
        let st = &self.inner.stats;
        let service = ServiceSection {
            uptime_us: self.inner.started.elapsed().as_micros() as u64,
            submitted: st.submitted.load(Ordering::SeqCst),
            completed: st.completed.load(Ordering::SeqCst),
            failed: st.failed.load(Ordering::SeqCst),
            queue_len: self.queue_len() as u64,
            peak_queue: st.peak_queue.load(Ordering::SeqCst) as u64,
            live_executors: self.live_executors() as u64,
            peak_executors: st.peak_executors.load(Ordering::SeqCst) as u64,
            busy_us: st.busy_us.load(Ordering::SeqCst),
        };
        MetricsSnapshot::new(service, counters::global().snapshot())
    }

    /// Block until the queue drains and all executors are idle.
    pub fn drain(&self) {
        loop {
            let empty = self.queue_len() == 0;
            let done = self.inner.stats.completed.load(Ordering::SeqCst)
                + self.inner.stats.failed.load(Ordering::SeqCst);
            let sub = self.inner.stats.submitted.load(Ordering::SeqCst);
            if empty && done >= sub {
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}

impl Drop for FalkonService {
    fn drop(&mut self) {
        self.inner.queue.shutdown();
        if let Some(h) = self.drp_thread.lock().unwrap().take() {
            let _ = h.join();
        }
        // Executor threads observe shutdown and exit; give them a moment.
        while self.inner.live.load(Ordering::SeqCst) > 0 {
            self.inner.queue.wake_all();
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

fn drp_loop(inner: Arc<Inner>) {
    let policy = inner.cfg.drp.clone();
    let ctrl = policy.controller();
    let mut pending_until: Option<Instant> = None;
    let mut pending_count = 0usize;
    loop {
        if inner.queue.is_shutdown() {
            return;
        }
        // Materialize matured allocations.
        if let Some(t) = pending_until {
            if Instant::now() >= t {
                for _ in 0..pending_count {
                    if inner.live.load(Ordering::SeqCst) < policy.max_executors {
                        spawn_executor(&inner);
                    }
                }
                pending_until = None;
                pending_count = 0;
            }
        }
        // Sizing is the shared policy core; this thread owns only the
        // clock (allocation delay, evaluation period). The queue length
        // read is lock-free — DRP never contends the dispatch path. At
        // most one allocation is in flight at a time: while one is
        // pending, the controller is not consulted again.
        if pending_until.is_none() {
            let queued = inner.queue.len();
            let live = inner.live.load(Ordering::SeqCst);
            let want = ctrl.to_allocate(queued, live);
            if want > 0 {
                if policy.allocation_delay.is_zero() {
                    for _ in 0..want {
                        spawn_executor(&inner);
                    }
                } else {
                    pending_until = Some(Instant::now() + policy.allocation_delay);
                    pending_count = want;
                }
            }
        }
        std::thread::sleep(policy.check_interval.min(Duration::from_millis(50)));
    }
}

fn spawn_executor(inner: &Arc<Inner>) {
    let id = inner.next_exec_id.fetch_add(1, Ordering::SeqCst);
    let live = inner.live.fetch_add(1, Ordering::SeqCst) + 1;
    // A load/compare/store here loses updates when two spawns interleave
    // (both read the old peak, the smaller store lands last and the gauge
    // goes *down*) — found by the model checker; pinned as
    // `peak_gauge_monotonic_under_concurrent_bumps` in
    // rust/tests/model_check.rs. fetch_max is the atomic monotone bump.
    // ord: monotone max over a gauge; no payload rides on this cell
    inner.stats.peak_executors.fetch_max(live, Ordering::Relaxed);
    let home = (id as usize) % inner.queue.num_shards();
    let inner = Arc::clone(inner);
    std::thread::Builder::new()
        .name(format!("falkon-exec-{id}"))
        .spawn(move || executor_loop(id, home, inner))
        .expect("spawn executor");
}

/// Attempt idle deregistration: CAS `live` down, never below the DRP
/// minimum (the floor decision is the policy core's; the CAS makes it
/// race-safe against concurrent timeouts). Returns true if this
/// executor should exit.
fn try_deregister(inner: &Inner) -> bool {
    let ctrl = inner.cfg.drp.controller();
    let mut live = inner.live.load(Ordering::SeqCst);
    loop {
        if !ctrl.may_deregister(live) {
            return false;
        }
        match inner.live.compare_exchange(
            live,
            live - 1,
            Ordering::SeqCst,
            Ordering::SeqCst,
        ) {
            Ok(_) => return true,
            Err(l) => live = l,
        }
    }
}

fn executor_loop(id: u64, home: usize, inner: Arc<Inner>) {
    let idle_timeout = inner.cfg.drp.idle_timeout;
    let overhead = inner.cfg.executor_overhead;
    // Reused pop buffer: the steady-state dispatch loop allocates
    // nothing.
    let mut batch: Vec<Queued> = Vec::with_capacity(DISPATCH_BATCH);
    // When this executor last transitioned to idle (for DRP shrink).
    let mut idle_since: Option<Instant> = None;
    loop {
        if inner.queue.is_shutdown() {
            inner.live.fetch_sub(1, Ordering::SeqCst);
            return;
        }
        // Fair-share pop size: batching amortizes the shard lock under
        // backlog, but never takes more than this executor's share of
        // the queue, so idle siblings are not starved of work.
        // ord: fairness heuristic; a stale pool size only skews batching
        let live = inner.live.load(Ordering::Relaxed).max(1);
        let fair = (inner.queue.len() / live).clamp(1, DISPATCH_BATCH);
        // Pull the next dispatch batch (home shard first, then steal).
        if inner.queue.try_pop_batch(home, fair, &mut batch) == 0 {
            // The park/wake protocol is miss-free (see queue.rs), so a
            // static pool blocks indefinitely at zero idle cost; with a
            // DRP idle timeout the wait doubles as the shrink clock.
            if idle_timeout.is_zero() {
                inner.queue.park(home, None);
            } else {
                let since = *idle_since.get_or_insert_with(Instant::now);
                let remaining = idle_timeout
                    .saturating_sub(since.elapsed())
                    .max(Duration::from_millis(1));
                inner.queue.park(home, Some(remaining));
                if inner.queue.is_shutdown() {
                    inner.live.fetch_sub(1, Ordering::SeqCst);
                    return;
                }
                if since.elapsed() >= idle_timeout {
                    if inner.queue.is_empty() && try_deregister(&inner) {
                        // Idle deregistration (DRP shrink).
                        return;
                    }
                    // At the DRP minimum (or work just landed): restart
                    // the idle clock rather than spinning on zero waits.
                    idle_since = Some(Instant::now());
                }
            }
            continue;
        }
        idle_since = None;
        counters::add(Counter::TasksDispatched, batch.len() as u64);
        for mut item in batch.drain(..) {
            let wait_us = item.enqueued.elapsed().as_micros() as u64;
            counters::observe(Hist::DispatchWaitUs, wait_us);
            let span = item.span;
            if let Some(h) = span {
                spans::record(h.event(Stage::Dispatched, spans::real_now_us()));
            }
            if !overhead.is_zero() {
                std::thread::sleep(overhead);
            }
            let t0 = Instant::now();
            if let Some(h) = span {
                // No separate stage-in step at the service level: data
                // is in place once the sandbox overhead is paid.
                let now = spans::real_now_us();
                spans::record(h.event(Stage::StagedIn, now));
                spans::record(h.event(Stage::ExecStart, now));
            }
            let outcome = (inner.runner)(&item.task);
            let exec_us = t0.elapsed().as_micros() as u64;
            if let Some(h) = span {
                spans::record(h.event(Stage::ExecEnd, spans::real_now_us()));
            }
            counters::observe(Hist::ExecUs, exec_us);
            // ord: commutative tally; readers take a racy snapshot
            inner.stats.busy_us.fetch_add(exec_us, Ordering::Relaxed);
            let ok = outcome.is_ok();
            if ok {
                inner.stats.completed.fetch_add(1, Ordering::SeqCst);
                counters::incr(Counter::TasksCompleted);
            } else {
                inner.stats.failed.fetch_add(1, Ordering::SeqCst);
                counters::incr(Counter::TasksFailed);
            }
            // Recycle the arg spine before the completion callback so
            // the pool is warm for any submit the callback triggers.
            inner.arg_pool.put(std::mem::take(&mut item.task.args));
            // The notification message.
            item.completion.deliver(TaskResult {
                id: item.task.id,
                ok,
                error: outcome.err().map(|e| format!("{e:#}")),
                executor: id,
                exec_us,
                wait_us,
            });
            if let Some(h) = span {
                spans::record(h.event(Stage::Notified, spans::real_now_us()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn noop_runner() -> AppRunner {
        Arc::new(|_t| Ok(()))
    }

    fn task(id: u64) -> AppTask {
        AppTask {
            id,
            key: format!("k{id}"),
            executable: "sleep0".into(),
            args: vec![],
            inputs: vec![],
            outputs: vec![],
        }
    }

    #[test]
    fn static_pool_processes_tasks() {
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(4),
                executor_overhead: Duration::ZERO,
            },
            noop_runner(),
        );
        let (tx, rx) = mpsc::channel();
        for i in 0..100 {
            let tx = tx.clone();
            svc.submit(task(i), Box::new(move |r| tx.send(r).unwrap()));
        }
        for _ in 0..100 {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(r.ok);
        }
        assert_eq!(svc.stats().completed.load(Ordering::SeqCst), 100);
        assert_eq!(svc.live_executors(), 4);
    }

    #[test]
    fn drp_grows_pool_on_queue_pressure() {
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy {
                    min_executors: 0,
                    max_executors: 8,
                    tasks_per_executor: 1,
                    allocation_delay: Duration::from_millis(30),
                    idle_timeout: Duration::from_millis(100),
                    check_interval: Duration::from_millis(5),
                },
                executor_overhead: Duration::ZERO,
            },
            Arc::new(|_t| {
                std::thread::sleep(Duration::from_millis(20));
                Ok(())
            }),
        );
        assert_eq!(svc.live_executors(), 0, "starts with zero executors");
        let (tx, rx) = mpsc::channel();
        for i in 0..32 {
            let tx = tx.clone();
            svc.submit(task(i), Box::new(move |r| tx.send(r).unwrap()));
        }
        for _ in 0..32 {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        let peak = svc.stats().peak_executors.load(Ordering::SeqCst);
        assert!(peak >= 2, "DRP grew the pool (peak {peak})");
        assert!(peak <= 8, "respected max (peak {peak})");
        // Idle timeout shrinks back toward min.
        std::thread::sleep(Duration::from_millis(400));
        assert!(
            svc.live_executors() <= 1,
            "idle executors deregistered: {}",
            svc.live_executors()
        );
    }

    #[test]
    fn submit_wait_roundtrip() {
        let svc = FalkonService::start(
            FalkonServiceConfig::default(),
            noop_runner(),
        );
        let r = svc.submit_wait(task(7));
        assert!(r.ok);
        assert_eq!(r.id, 7);
    }

    #[test]
    fn failures_reported() {
        let svc = FalkonService::start(
            FalkonServiceConfig::default(),
            Arc::new(|t| {
                if t.id % 2 == 0 {
                    anyhow::bail!("even ids fail")
                }
                Ok(())
            }),
        );
        assert!(!svc.submit_wait(task(2)).ok);
        assert!(svc.submit_wait(task(3)).ok);
        assert_eq!(svc.stats().failed.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn throughput_exceeds_paper_487() {
        // Sleep-0 dispatch throughput through the full submit/dispatch/
        // notify path must comfortably exceed the paper's 487 tasks/s.
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(4),
                executor_overhead: Duration::ZERO,
            },
            noop_runner(),
        );
        let n = 5000u64;
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        for i in 0..n {
            let tx = tx.clone();
            svc.submit(task(i), Box::new(move |r| tx.send(r).unwrap()));
        }
        for _ in 0..n {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
        let rate = n as f64 / t0.elapsed().as_secs_f64();
        assert!(rate > 487.0, "dispatch rate {rate:.0} tasks/s");
    }

    #[test]
    fn batched_submit_roundtrip() {
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(4),
                executor_overhead: Duration::ZERO,
            },
            noop_runner(),
        );
        let (tx, rx) = mpsc::channel();
        let batch: Vec<(AppTask, TaskDone)> = (0..256u64)
            .map(|i| {
                let tx = tx.clone();
                let done: TaskDone = Box::new(move |r| tx.send(r).unwrap());
                (task(i), done)
            })
            .collect();
        svc.submit_batch(batch);
        let mut ids = std::collections::HashSet::new();
        for _ in 0..256 {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(r.ok);
            ids.insert(r.id);
        }
        assert_eq!(ids.len(), 256, "every task completed exactly once");
        assert_eq!(svc.stats().completed.load(Ordering::SeqCst), 256);
    }

    #[test]
    fn bundle_submit_aggregates_in_order() {
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(3),
                executor_overhead: Duration::ZERO,
            },
            Arc::new(|t| {
                if t.id == 4 {
                    anyhow::bail!("four fails")
                }
                Ok(())
            }),
        );
        let (tx, rx) = mpsc::channel();
        svc.submit_bundle(
            (0..8).map(task).collect(),
            Box::new(move |rs| tx.send(rs).unwrap()),
        );
        let rs = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(rs.len(), 8);
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(r.id, i as u64, "bundle results keep order");
            assert_eq!(r.ok, r.id != 4);
        }
    }

    #[test]
    fn empty_bundle_completes_inline() {
        let svc = FalkonService::start(FalkonServiceConfig::default(), noop_runner());
        let (tx, rx) = mpsc::channel();
        svc.submit_bundle(vec![], Box::new(move |rs| tx.send(rs).unwrap()));
        assert!(rx.recv_timeout(Duration::from_secs(1)).unwrap().is_empty());
    }

    #[test]
    fn scrape_snapshot_reflects_service_gauges() {
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(3),
                executor_overhead: Duration::ZERO,
            },
            noop_runner(),
        );
        for i in 0..20 {
            svc.submit_wait(task(i));
        }
        let snap = svc.scrape_snapshot();
        assert_eq!(snap.version, crate::telemetry::SNAPSHOT_VERSION);
        assert_eq!(snap.service.submitted, 20);
        assert_eq!(snap.service.completed, 20);
        assert_eq!(snap.service.failed, 0);
        assert_eq!(snap.service.queue_len, 0);
        assert_eq!(snap.service.live_executors, 3);
        assert_eq!(snap.service.peak_executors, 3);
        // The counter registry is process-global: assert shape plus a
        // floor (other tests may have recorded into it concurrently).
        assert!(snap.counters.get("tasks_submitted") >= 20);
        assert!(snap.counters.hist_count("exec_us") >= 20);
    }

    #[test]
    fn drain_waits_for_completion() {
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(2),
                executor_overhead: Duration::ZERO,
            },
            Arc::new(|_t| {
                std::thread::sleep(Duration::from_millis(10));
                Ok(())
            }),
        );
        for i in 0..10 {
            svc.submit(task(i), Box::new(|_r| {}));
        }
        svc.drain();
        assert_eq!(svc.stats().completed.load(Ordering::SeqCst), 10);
        assert_eq!(svc.queue_len(), 0);
    }
}
