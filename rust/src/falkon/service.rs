//! The Falkon execution service (real clock).
//!
//! Architecture (paper Figure 5): clients submit tasks to the service
//! queue; the streamlined dispatcher hands each task to an idle executor
//! (two logical message exchanges per dispatch: task out, result back);
//! DRP watches the queue and grows/shrinks the executor pool, acquiring
//! resources through a (simulated-latency) LRM allocation call and
//! releasing executors that stay idle past the idle timeout.
//!
//! Implementation notes: executors are pull-based worker threads sharing
//! the service queue — the pop *is* the dispatch message, the completion
//! callback is the notification message. This keeps the dispatcher
//! critical section to a queue pop, which is what "streamlined" means
//! operationally; the paper's 487 tasks/s corresponds to ~2 ms of
//! dispatcher work per task, our target is to beat that comfortably
//! (see benches/falkon_micro.rs).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::providers::{AppRunner, AppTask, TaskResult};

/// Dynamic resource provisioning policy (real clock).
#[derive(Debug, Clone)]
pub struct RealDrpPolicy {
    pub min_executors: usize,
    pub max_executors: usize,
    /// Target one executor per this many queued tasks.
    pub tasks_per_executor: usize,
    /// Simulated allocation latency (GRAM4+PBS round trip). Zero for
    /// pure-throughput benchmarks.
    pub allocation_delay: Duration,
    /// Deregister executors idle this long (Duration::ZERO = never).
    pub idle_timeout: Duration,
    /// DRP evaluation period.
    pub check_interval: Duration,
}

impl RealDrpPolicy {
    /// A fixed-size pool: provisioned once, never shrinks.
    pub fn static_pool(n: usize) -> Self {
        Self {
            min_executors: n,
            max_executors: n,
            tasks_per_executor: 1,
            allocation_delay: Duration::ZERO,
            idle_timeout: Duration::ZERO,
            check_interval: Duration::from_millis(50),
        }
    }

    /// On-demand provisioning between bounds.
    pub fn dynamic(min: usize, max: usize) -> Self {
        Self {
            min_executors: min,
            max_executors: max,
            tasks_per_executor: 1,
            allocation_delay: Duration::ZERO,
            idle_timeout: Duration::from_millis(500),
            check_interval: Duration::from_millis(20),
        }
    }
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct FalkonServiceConfig {
    pub drp: RealDrpPolicy,
    /// Per-task executor-side overhead (sandbox setup simulation); zero
    /// for raw dispatch benchmarks.
    pub executor_overhead: Duration,
}

impl Default for FalkonServiceConfig {
    fn default() -> Self {
        Self {
            drp: RealDrpPolicy::static_pool(4),
            executor_overhead: Duration::ZERO,
        }
    }
}

/// Aggregate service statistics.
#[derive(Debug, Default)]
pub struct ServiceStats {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub peak_queue: AtomicUsize,
    pub peak_executors: AtomicUsize,
    pub busy_us: AtomicU64,
}

/// Completion callback per task.
pub type TaskDone = Box<dyn FnOnce(TaskResult) + Send>;

struct Queued {
    task: AppTask,
    done: TaskDone,
    enqueued: Instant,
}

struct Inner {
    cfg: FalkonServiceConfig,
    runner: AppRunner,
    queue: Mutex<VecDeque<Queued>>,
    cv: Condvar,
    live: AtomicUsize,
    next_exec_id: AtomicU64,
    shutdown: AtomicBool,
    stats: ServiceStats,
}

/// The Falkon service handle.
pub struct FalkonService {
    inner: Arc<Inner>,
    drp_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl FalkonService {
    /// Start the service with the given app runner.
    pub fn start(cfg: FalkonServiceConfig, runner: AppRunner) -> Arc<Self> {
        let inner = Arc::new(Inner {
            cfg: cfg.clone(),
            runner,
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            live: AtomicUsize::new(0),
            next_exec_id: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            stats: ServiceStats::default(),
        });
        // Bootstrap the minimum pool.
        for _ in 0..cfg.drp.min_executors {
            spawn_executor(&inner);
        }
        let svc = Arc::new(Self { inner, drp_thread: Mutex::new(None) });
        // DRP manager thread.
        let inner2 = Arc::clone(&svc.inner);
        let h = std::thread::Builder::new()
            .name("falkon-drp".into())
            .spawn(move || drp_loop(inner2))
            .expect("spawn drp");
        *svc.drp_thread.lock().unwrap() = Some(h);
        svc
    }

    /// Submit one task.
    pub fn submit(&self, task: AppTask, done: TaskDone) {
        let inner = &self.inner;
        inner.stats.submitted.fetch_add(1, Ordering::Relaxed);
        let mut q = inner.queue.lock().unwrap();
        q.push_back(Queued { task, done, enqueued: Instant::now() });
        let len = q.len();
        let peak = inner.stats.peak_queue.load(Ordering::Relaxed);
        if len > peak {
            inner.stats.peak_queue.store(len, Ordering::Relaxed);
        }
        drop(q);
        inner.cv.notify_one();
    }

    /// Submit and block for the result (client convenience).
    pub fn submit_wait(&self, task: AppTask) -> TaskResult {
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(task, Box::new(move |r| {
            let _ = tx.send(r);
        }));
        rx.recv().expect("service dropped")
    }

    pub fn stats(&self) -> &ServiceStats {
        &self.inner.stats
    }

    pub fn queue_len(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    pub fn live_executors(&self) -> usize {
        self.inner.live.load(Ordering::SeqCst)
    }

    /// Block until the queue drains and all executors are idle.
    pub fn drain(&self) {
        loop {
            let empty = self.queue_len() == 0;
            let done = self.inner.stats.completed.load(Ordering::SeqCst)
                + self.inner.stats.failed.load(Ordering::SeqCst);
            let sub = self.inner.stats.submitted.load(Ordering::SeqCst);
            if empty && done >= sub {
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}

impl Drop for FalkonService {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();
        if let Some(h) = self.drp_thread.lock().unwrap().take() {
            let _ = h.join();
        }
        // Executor threads observe shutdown and exit; give them a moment.
        while self.inner.live.load(Ordering::SeqCst) > 0 {
            self.inner.cv.notify_all();
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

fn drp_loop(inner: Arc<Inner>) {
    let policy = inner.cfg.drp.clone();
    let mut pending_until: Option<Instant> = None;
    let mut pending_count = 0usize;
    loop {
        if inner.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Materialize matured allocations.
        if let Some(t) = pending_until {
            if Instant::now() >= t {
                for _ in 0..pending_count {
                    if inner.live.load(Ordering::SeqCst) < policy.max_executors {
                        spawn_executor(&inner);
                    }
                }
                pending_until = None;
                pending_count = 0;
            }
        }
        // Policy: one executor per tasks_per_executor queued.
        let queued = inner.queue.lock().unwrap().len();
        let live = inner.live.load(Ordering::SeqCst);
        let desired = queued
            .div_ceil(policy.tasks_per_executor.max(1))
            .clamp(policy.min_executors, policy.max_executors)
            .max(policy.min_executors);
        if desired > live && pending_until.is_none() {
            let want = desired - live;
            if policy.allocation_delay.is_zero() {
                for _ in 0..want {
                    spawn_executor(&inner);
                }
            } else {
                pending_until = Some(Instant::now() + policy.allocation_delay);
                pending_count = want;
            }
        }
        std::thread::sleep(policy.check_interval.min(Duration::from_millis(50)));
    }
}

fn spawn_executor(inner: &Arc<Inner>) {
    let id = inner.next_exec_id.fetch_add(1, Ordering::SeqCst);
    let live = inner.live.fetch_add(1, Ordering::SeqCst) + 1;
    let peak = inner.stats.peak_executors.load(Ordering::Relaxed);
    if live > peak {
        inner.stats.peak_executors.store(live, Ordering::Relaxed);
    }
    let inner = Arc::clone(inner);
    std::thread::Builder::new()
        .name(format!("falkon-exec-{id}"))
        .spawn(move || executor_loop(id, inner))
        .expect("spawn executor");
}

fn executor_loop(id: u64, inner: Arc<Inner>) {
    let idle_timeout = inner.cfg.drp.idle_timeout;
    let overhead = inner.cfg.executor_overhead;
    loop {
        // Pull the next task (the dispatch message).
        let item = {
            let mut q = inner.queue.lock().unwrap();
            loop {
                if inner.shutdown.load(Ordering::SeqCst) {
                    inner.live.fetch_sub(1, Ordering::SeqCst);
                    return;
                }
                if let Some(item) = q.pop_front() {
                    break Some(item);
                }
                if idle_timeout.is_zero() {
                    q = inner.cv.wait(q).unwrap_or_else(|e| e.into_inner());
                } else {
                    let (g, t) = inner
                        .cv
                        .wait_timeout(q, idle_timeout)
                        .unwrap_or_else(|e| e.into_inner());
                    q = g;
                    if t.timed_out()
                        && q.is_empty()
                        && inner.live.load(Ordering::SeqCst)
                            > inner.cfg.drp.min_executors
                    {
                        // Idle deregistration (DRP shrink).
                        break None;
                    }
                }
            }
        };
        let Some(item) = item else {
            inner.live.fetch_sub(1, Ordering::SeqCst);
            return;
        };
        let wait_us = item.enqueued.elapsed().as_micros() as u64;
        if !overhead.is_zero() {
            std::thread::sleep(overhead);
        }
        let t0 = Instant::now();
        let outcome = (inner.runner)(&item.task);
        let exec_us = t0.elapsed().as_micros() as u64;
        inner.stats.busy_us.fetch_add(exec_us, Ordering::Relaxed);
        let ok = outcome.is_ok();
        if ok {
            inner.stats.completed.fetch_add(1, Ordering::SeqCst);
        } else {
            inner.stats.failed.fetch_add(1, Ordering::SeqCst);
        }
        // The notification message.
        (item.done)(TaskResult {
            id: item.task.id,
            ok,
            error: outcome.err().map(|e| format!("{e:#}")),
            executor: id,
            exec_us,
            wait_us,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn noop_runner() -> AppRunner {
        Arc::new(|_t| Ok(()))
    }

    fn task(id: u64) -> AppTask {
        AppTask {
            id,
            key: format!("k{id}"),
            executable: "sleep0".into(),
            args: vec![],
            inputs: vec![],
            outputs: vec![],
        }
    }

    #[test]
    fn static_pool_processes_tasks() {
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(4),
                executor_overhead: Duration::ZERO,
            },
            noop_runner(),
        );
        let (tx, rx) = mpsc::channel();
        for i in 0..100 {
            let tx = tx.clone();
            svc.submit(task(i), Box::new(move |r| tx.send(r).unwrap()));
        }
        for _ in 0..100 {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(r.ok);
        }
        assert_eq!(svc.stats().completed.load(Ordering::SeqCst), 100);
        assert_eq!(svc.live_executors(), 4);
    }

    #[test]
    fn drp_grows_pool_on_queue_pressure() {
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy {
                    min_executors: 0,
                    max_executors: 8,
                    tasks_per_executor: 1,
                    allocation_delay: Duration::from_millis(30),
                    idle_timeout: Duration::from_millis(100),
                    check_interval: Duration::from_millis(5),
                },
                executor_overhead: Duration::ZERO,
            },
            Arc::new(|_t| {
                std::thread::sleep(Duration::from_millis(20));
                Ok(())
            }),
        );
        assert_eq!(svc.live_executors(), 0, "starts with zero executors");
        let (tx, rx) = mpsc::channel();
        for i in 0..32 {
            let tx = tx.clone();
            svc.submit(task(i), Box::new(move |r| tx.send(r).unwrap()));
        }
        for _ in 0..32 {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        let peak = svc.stats().peak_executors.load(Ordering::SeqCst);
        assert!(peak >= 2, "DRP grew the pool (peak {peak})");
        assert!(peak <= 8, "respected max (peak {peak})");
        // Idle timeout shrinks back toward min.
        std::thread::sleep(Duration::from_millis(400));
        assert!(
            svc.live_executors() <= 1,
            "idle executors deregistered: {}",
            svc.live_executors()
        );
    }

    #[test]
    fn submit_wait_roundtrip() {
        let svc = FalkonService::start(
            FalkonServiceConfig::default(),
            noop_runner(),
        );
        let r = svc.submit_wait(task(7));
        assert!(r.ok);
        assert_eq!(r.id, 7);
    }

    #[test]
    fn failures_reported() {
        let svc = FalkonService::start(
            FalkonServiceConfig::default(),
            Arc::new(|t| {
                if t.id % 2 == 0 {
                    anyhow::bail!("even ids fail")
                }
                Ok(())
            }),
        );
        assert!(!svc.submit_wait(task(2)).ok);
        assert!(svc.submit_wait(task(3)).ok);
        assert_eq!(svc.stats().failed.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn throughput_exceeds_paper_487() {
        // Sleep-0 dispatch throughput through the full submit/dispatch/
        // notify path must comfortably exceed the paper's 487 tasks/s.
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(4),
                executor_overhead: Duration::ZERO,
            },
            noop_runner(),
        );
        let n = 5000u64;
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        for i in 0..n {
            let tx = tx.clone();
            svc.submit(task(i), Box::new(move |r| tx.send(r).unwrap()));
        }
        for _ in 0..n {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
        let rate = n as f64 / t0.elapsed().as_secs_f64();
        assert!(rate > 487.0, "dispatch rate {rate:.0} tasks/s");
    }

    #[test]
    fn drain_waits_for_completion() {
        let svc = FalkonService::start(
            FalkonServiceConfig {
                drp: RealDrpPolicy::static_pool(2),
                executor_overhead: Duration::ZERO,
            },
            Arc::new(|_t| {
                std::thread::sleep(Duration::from_millis(10));
                Ok(())
            }),
        );
        for i in 0..10 {
            svc.submit(task(i), Box::new(|_r| {}));
        }
        svc.drain();
        assert_eq!(svc.stats().completed.load(Ordering::SeqCst), 10);
        assert_eq!(svc.queue_len(), 0);
    }
}
