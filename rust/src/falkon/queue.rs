//! Sharded work queue with work stealing — the dispatch core's data
//! structure (paper §4: the "streamlined dispatcher").
//!
//! The seed implementation funneled every dispatch through one global
//! `Mutex<VecDeque>` + `Condvar`, serializing submitters against every
//! executor. This queue splits the deque into shards; as of the hot-path
//! overhaul each shard's fast path is a **vendored lock-free bounded
//! ring** (Vyukov-style MPMC array queue: per-slot sequence numbers, CAS
//! on the push/pop cursors — no external deps) with a Mutex-guarded
//! `VecDeque` overflow spillover preserving unbounded capacity and FIFO
//! order when a burst outruns the ring:
//!
//! - **Submitters** round-robin across shards (a CAS-bounded ring write
//!   per push; [`ShardedQueue::push_batch`] wakes once per shard *per
//!   batch*, not per task).
//! - **Executors** drain their home shard in batches and **steal** half
//!   of another shard's backlog when their own is empty, so imbalance
//!   self-corrects. The steal path is the ring's CAS pop — stealers and
//!   the home executor contend on an atomic cursor, not a lock.
//! - **Wakeups are targeted**: a push notifies sleepers on the receiving
//!   shard (falling back to any sleeping shard), never broadcasting to
//!   the whole pool — no thundering herd on single-task submits.
//!
//! The sleep/wake protocol is miss-free without polling. A parker takes
//! the shard's (otherwise uncontended) park lock, registers as a sleeper,
//! and only then checks for published work; the submit side publishes the
//! new length (SeqCst) *before* reading sleeper counts, and notifies under
//! the same park lock. By the SeqCst total order either the parker sees
//! the published work and never sleeps, or the waker sees the registered
//! sleeper and its notify is serialized (by the park lock) after the
//! parker entered its wait. Idle workers therefore block indefinitely at
//! zero CPU cost; timeouts exist only as the DRP idle-deregistration
//! clock. See DESIGN.md §10.3 for the full memory-ordering argument.
//!
//! [`MutexShardedQueue`] keeps the previous lock-per-shard
//! implementation verbatim as the contention baseline
//! `benches/falkon_micro.rs` measures the ring against.
//!
//! hot-path: `push`/`try_pop_batch` run once per task on the dispatch
//! floor — pallas-lint bans steady-state allocation here. All sync
//! primitives come from `crate::check::sync` so the model checker
//! (`--features model_check`) can interpose; the default build re-exports
//! std types and compiles identically.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::time::Duration;

use crate::check::sync::{AtomicBool, AtomicUsize, CheckCell, Condvar, Mutex};
use crate::telemetry::counters::{self, Counter, Hist};

/// Cap on queue shards. Tuned from `benches/falkon_micro.rs` (see
/// DESIGN.md §2.5): past 8 shards the per-shard locks are essentially
/// uncontended on the 4–16-executor pools the benches exercise, while
/// every additional shard lengthens the executor's empty-shard steal
/// scan and the submit side's wake scan. 8 is the knee.
pub const MAX_SHARDS: usize = 8;

/// Max tasks an executor pops per batch. Tuned from
/// `benches/falkon_micro.rs` (see DESIGN.md §2.5): 32 amortizes the
/// per-batch bookkeeping to noise under backlog without letting one
/// executor monopolize a burst — the actual pop size is further capped
/// at the executor's fair share of the current backlog.
pub const DISPATCH_BATCH: usize = 32;

/// Per-shard lock-free ring capacity (power of two). 1024 slots absorb
/// any burst the dispatch loop produces between drains; deeper backlogs
/// (the paper queues 1.5 M tasks) spill to the shard's overflow deque.
#[cfg(not(feature = "model_check"))]
const RING_CAP: usize = 1024;

/// Tiny ring under model check so wraparound, full-ring and spillover
/// paths are all reachable within a bounded schedule exploration.
#[cfg(feature = "model_check")]
const RING_CAP: usize = 4;

/// Pads the ring cursors to separate cache lines so producers bouncing
/// `tail` don't false-share with consumers bouncing `head`.
#[repr(align(64))]
struct CachePadded<T>(T);

struct Slot<T> {
    /// Vyukov sequence number: `pos` when the slot is free for the
    /// producer of ticket `pos`, `pos + 1` once its value is readable,
    /// `pos + cap` once consumed (free for the next lap's producer).
    seq: AtomicUsize,
    /// Plain payload memory handed off by the `seq` protocol; the
    /// `CheckCell` facade lets the model checker's race detector verify
    /// that handoff (zero-cost `UnsafeCell` in the default build).
    val: CheckCell<T>,
}

/// Vendored bounded MPMC ring (Vyukov array queue). Producers and
/// consumers claim tickets by CAS on `tail`/`head`; each slot's `seq`
/// gates access so a claimed-but-unwritten slot is never read and a
/// claimed-but-unread slot is never overwritten.
struct Ring<T> {
    slots: Box<[Slot<T>]>,
    mask: usize,
    head: CachePadded<AtomicUsize>,
    tail: CachePadded<AtomicUsize>,
}

// SAFETY: values move through the ring exactly once (ownership is
// transferred by the seq handshake: the Release store on `seq` after a
// write happens-before the Acquire load that permits the read), so the
// ring is Sync whenever T may cross threads.
unsafe impl<T: Send> Send for Ring<T> {}
unsafe impl<T: Send> Sync for Ring<T> {}

impl<T> Ring<T> {
    // lint: allow(hot-path-alloc) — one-time construction, not dispatch
    fn new(cap: usize) -> Self {
        assert!(cap.is_power_of_two());
        Self {
            slots: (0..cap)
                .map(|i| Slot {
                    seq: AtomicUsize::new(i),
                    val: CheckCell::uninit(),
                })
                .collect::<Vec<_>>()
                .into_boxed_slice(),
            mask: cap - 1,
            head: CachePadded(AtomicUsize::new(0)),
            tail: CachePadded(AtomicUsize::new(0)),
        }
    }

    /// Lock-free push; returns the item back when the ring is full.
    fn push(&self, item: T) -> Result<(), T> {
        // ord: cursor scan only; the seq Acquire below is what orders
        let mut pos = self.tail.0.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            // ord: pairs with the Release seq stores in push/pop — seeing
            // `pos` here means the previous lap's value was fully read
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - pos as isize;
            if dif == 0 {
                // Slot free for this ticket: claim it.
                match self.tail.0.compare_exchange_weak(
                    pos,
                    pos + 1,
                    // ord: ticket claim only; the value is published by
                    // the seq Release store, not by this cursor CAS
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS gave this thread exclusive
                        // ownership of the slot until the seq store
                        // publishes it to consumers.
                        unsafe { slot.val.write(item) };
                        // ord: publishes the written value to the
                        // consumer's seq Acquire load
                        slot.seq.store(pos + 1, Ordering::Release);
                        return Ok(());
                    }
                    Err(p) => pos = p,
                }
            } else if dif < 0 {
                // A full lap behind: the ring is full.
                return Err(item);
            } else {
                // ord: stale ticket; re-read the cursor and retry
                pos = self.tail.0.load(Ordering::Relaxed);
            }
        }
    }

    /// Lock-free pop (this is also the steal path: stealers CAS the
    /// same `head` cursor). Returns `None` when empty.
    fn pop(&self) -> Option<T> {
        // ord: cursor scan only; the seq Acquire below is what orders
        let mut pos = self.head.0.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            // ord: pairs with the Release seq store in push — seeing
            // `pos + 1` means the producer's value write is visible
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - (pos + 1) as isize;
            if dif == 0 {
                match self.head.0.compare_exchange_weak(
                    pos,
                    pos + 1,
                    // ord: ticket claim only; value visibility came from
                    // the seq Acquire, recycling goes via seq Release
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS gave this thread exclusive
                        // ownership of the published value; the seq
                        // store below recycles the slot for producers.
                        let item = unsafe { slot.val.read() };
                        // ord: publishes the completed read — the next
                        // lap's producer may overwrite the slot
                        slot.seq.store(pos + self.mask + 1, Ordering::Release);
                        return Some(item);
                    }
                    Err(p) => pos = p,
                }
            } else if dif < 0 {
                // Empty (or a push claimed the slot but hasn't
                // published yet — the caller re-checks `len`).
                return None;
            } else {
                // ord: stale ticket; re-read the cursor and retry
                pos = self.head.0.load(Ordering::Relaxed);
            }
        }
    }

    /// Approximate occupancy (cursors race; exact counts live in the
    /// queue-level `len` atomic).
    fn len_estimate(&self) -> usize {
        // ord: advisory estimate — staleness only biases the steal scan
        let tail = self.tail.0.load(Ordering::Relaxed);
        let head = self.head.0.load(Ordering::Relaxed);
        tail.saturating_sub(head)
    }
}

impl<T> Drop for Ring<T> {
    fn drop(&mut self) {
        while self.pop().is_some() {}
    }
}

struct Shard<T> {
    /// Lock-free fast path.
    ring: Ring<T>,
    /// Spillover preserving unbounded capacity. Invariant: while the
    /// overflow is non-empty, pushes append here (never to the ring), so
    /// every overflow item is newer than every ring item and per-shard
    /// FIFO order survives the spill.
    overflow: Mutex<VecDeque<T>>,
    overflow_len: AtomicUsize,
    /// Park lock: serializes sleeper registration/notify only — never
    /// touched by the push/pop fast paths.
    park: Mutex<()>,
    cv: Condvar,
    /// Workers currently blocked on `cv` (maintained inside `park`).
    sleepers: AtomicUsize,
}

impl<T> Shard<T> {
    fn backlog_estimate(&self) -> usize {
        // ord: advisory estimate — staleness only biases the steal scan
        self.ring.len_estimate() + self.overflow_len.load(Ordering::Relaxed)
    }
}

/// A multi-shard MPMC work queue with batched operations and stealing.
/// Push/pop are lock-free in the steady state (bounded-ring fast path);
/// locks remain only on the overflow spillover and the park/wake path.
pub struct ShardedQueue<T> {
    shards: Vec<Shard<T>>,
    /// Total queued items across shards (lock-free readers: DRP, stats).
    len: AtomicUsize,
    /// High-water mark of `len`, maintained exactly at push time.
    peak: AtomicUsize,
    /// Total sleepers across shards: lets the submit fast path skip the
    /// wake scan entirely when the pool is busy.
    total_sleepers: AtomicUsize,
    /// Round-robin submit cursor.
    rr: AtomicUsize,
    shutdown: AtomicBool,
}

impl<T> ShardedQueue<T> {
    // lint: allow(hot-path-alloc) — one-time construction, not dispatch
    pub fn new(nshards: usize) -> Self {
        let n = nshards.max(1);
        Self {
            shards: (0..n)
                .map(|_| Shard {
                    ring: Ring::new(RING_CAP),
                    overflow: Mutex::new(VecDeque::new()),
                    overflow_len: AtomicUsize::new(0),
                    park: Mutex::new(()),
                    cv: Condvar::new(),
                    sleepers: AtomicUsize::new(0),
                })
                .collect(),
            len: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            total_sleepers: AtomicUsize::new(0),
            rr: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        }
    }

    /// Monotonic CAS-max on the peak-length gauge.
    fn bump_peak(&self, candidate: usize) {
        // ord: monotone max over a gauge; no payload rides on this cell
        let mut cur = self.peak.load(Ordering::Relaxed);
        while candidate > cur {
            match self.peak.compare_exchange_weak(
                cur,
                candidate,
                // ord: monotone max over a gauge; publishes no payload
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }

    /// High-water mark of the queue length, exact as of each push.
    pub fn peak(&self) -> usize {
        // ord: gauge read; was SeqCst, which bought nothing — the writer
        // side is Relaxed, so this never synchronized anything
        self.peak.load(Ordering::Relaxed)
    }

    /// Number of shards (fixed at construction).
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total queued items across all shards (lock-free read).
    pub fn len(&self) -> usize {
        self.len.load(Ordering::SeqCst)
    }

    /// True when no shard holds work (lock-free read).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert into one shard: lock-free ring unless the overflow is
    /// engaged (see the `Shard::overflow` FIFO invariant).
    fn insert(&self, shard: &Shard<T>, item: T) {
        // ord: pairs with the Release stores in spill/drain — a zero read
        // here means the overflow's emptiness is an established fact
        if shard.overflow_len.load(Ordering::Acquire) == 0 {
            match shard.ring.push(item) {
                Ok(()) => return,
                Err(item) => Self::spill(shard, item),
            }
        } else {
            Self::spill(shard, item);
        }
    }

    fn spill(shard: &Shard<T>, item: T) {
        counters::incr(Counter::QueueOverflowed);
        let mut q = shard.overflow.lock().unwrap();
        q.push_back(item);
        // ord: pairs with the Acquire load in insert/drain_shard
        shard.overflow_len.store(q.len(), Ordering::Release);
    }

    /// Push one item (lock-free fast path, one targeted wakeup).
    pub fn push(&self, item: T) {
        // ord: round-robin cursor; any distribution is correct
        let s = self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        self.insert(&self.shards[s], item);
        let new_len = self.len.fetch_add(1, Ordering::SeqCst) + 1;
        counters::incr(Counter::QueuePushed);
        counters::observe(Hist::QueueDepth, new_len as u64);
        self.bump_peak(new_len);
        self.wake(s, 1);
    }

    /// Push a whole batch: items are spread round-robin in contiguous
    /// chunks, costing one wakeup per *shard*, not per task.
    pub fn push_batch(&self, items: Vec<T>) {
        let k = items.len();
        if k == 0 {
            return;
        }
        let n = self.shards.len();
        // ord: round-robin cursor; any distribution is correct
        let start = self.rr.fetch_add(k, Ordering::Relaxed);
        let chunk = k.div_ceil(n);
        let mut items = items.into_iter();
        let mut pushed = 0usize;
        let mut i = 0usize;
        let mut max_len = 0usize;
        while pushed < k {
            let s = (start + i) % n;
            i += 1;
            let take = chunk.min(k - pushed);
            let shard = &self.shards[s];
            for _ in 0..take {
                self.insert(shard, items.next().expect("batch length"));
            }
            max_len = max_len.max(self.len.fetch_add(take, Ordering::SeqCst) + take);
            self.wake(s, take);
            pushed += take;
        }
        counters::add(Counter::QueuePushed, k as u64);
        counters::observe(Hist::QueueDepth, max_len as u64);
        self.bump_peak(max_len);
    }

    /// Drain up to `target` items from one shard in FIFO order: ring
    /// first (older), then the overflow spillover.
    fn drain_shard(shard: &Shard<T>, target: usize, out: &mut Vec<T>) -> usize {
        let mut took = 0usize;
        while took < target {
            match shard.ring.pop() {
                Some(v) => {
                    out.push(v);
                    took += 1;
                }
                None => break,
            }
        }
        // ord: pairs with the Release stores in spill/drain — skipping
        // the lock on zero is safe because only drains shrink the count
        if took < target && shard.overflow_len.load(Ordering::Acquire) > 0 {
            let mut q = shard.overflow.lock().unwrap();
            while took < target {
                match q.pop_front() {
                    Some(v) => {
                        out.push(v);
                        took += 1;
                    }
                    None => break,
                }
            }
            // ord: pairs with the Acquire load in insert/drain_shard
            shard.overflow_len.store(q.len(), Ordering::Release);
        }
        took
    }

    /// Pop up to `max` items into `out`, preferring the caller's home
    /// shard and stealing half of a sibling's backlog otherwise. Returns
    /// the number of items appended. Non-blocking; lock-free unless the
    /// overflow spillover is engaged.
    pub fn try_pop_batch(&self, home: usize, max: usize, out: &mut Vec<T>) -> usize {
        let n = self.shards.len();
        let home = home % n;
        for off in 0..n {
            let s = (home + off) % n;
            let shard = &self.shards[s];
            let backlog = shard.backlog_estimate();
            if backlog == 0 {
                continue;
            }
            // Home shard: take a full batch (FIFO). Sibling: steal half
            // so the owner keeps local work.
            let target = if off == 0 {
                max
            } else {
                backlog.div_ceil(2).min(max)
            };
            let took = Self::drain_shard(shard, target, out);
            if took > 0 {
                if off > 0 {
                    counters::add(Counter::QueueStolen, took as u64);
                }
                self.len.fetch_sub(took, Ordering::SeqCst);
                return took;
            }
        }
        0
    }

    /// Block on the home shard until a wakeup, the timeout (if any), or
    /// shutdown. Returns `true` if the wait timed out (the caller may
    /// then apply idle-deregistration policy). Returns immediately if
    /// work or shutdown is already visible.
    ///
    /// Miss-free protocol: the sleeper registers *before* re-checking
    /// for work, inside the park lock. A concurrent submit publishes
    /// its length (SeqCst) first and then scans sleeper counts under the
    /// same park locks, so one side always sees the other (DESIGN.md
    /// §10.3).
    pub fn park(&self, home: usize, timeout: Option<Duration>) -> bool {
        let shard = &self.shards[home % self.shards.len()];
        let mut g = shard.park.lock().unwrap();
        shard.sleepers.fetch_add(1, Ordering::SeqCst);
        self.total_sleepers.fetch_add(1, Ordering::SeqCst);
        let timed_out = if self.len.load(Ordering::SeqCst) > 0
            || self.shutdown.load(Ordering::SeqCst)
        {
            false
        } else {
            match timeout {
                Some(t) => {
                    let (g2, to) = shard
                        .cv
                        .wait_timeout(g, t)
                        .unwrap_or_else(|e| e.into_inner());
                    g = g2;
                    to.timed_out()
                }
                None => {
                    g = shard.cv.wait(g).unwrap_or_else(|e| e.into_inner());
                    false
                }
            }
        };
        shard.sleepers.fetch_sub(1, Ordering::SeqCst);
        self.total_sleepers.fetch_sub(1, Ordering::SeqCst);
        drop(g);
        timed_out
    }

    /// Wake up to `count` sleeping workers, preferring the shard that
    /// just received work and falling back to any shard with sleepers.
    /// Sleeper counts are read under each shard's park lock, which pairs
    /// with `park`'s register-then-check to make wakeups miss-free; the
    /// `total_sleepers` fast path skips the scan when the pool is busy.
    fn wake(&self, preferred: usize, count: usize) {
        if self.total_sleepers.load(Ordering::SeqCst) == 0 {
            return;
        }
        let n = self.shards.len();
        let mut remaining = count;
        for off in 0..n {
            if remaining == 0 {
                return;
            }
            let shard = &self.shards[(preferred + off) % n];
            let guard = shard.park.lock().unwrap();
            let sleeping = shard.sleepers.load(Ordering::SeqCst);
            if sleeping == 0 {
                continue;
            }
            if remaining >= sleeping {
                shard.cv.notify_all();
            } else {
                for _ in 0..remaining {
                    shard.cv.notify_one();
                }
            }
            drop(guard);
            remaining = remaining.saturating_sub(sleeping);
        }
    }

    /// Wake every sleeping worker on every shard (shutdown/drain paths
    /// only — this is deliberately not used on the submit hot path).
    /// Locks each park mutex so a worker between its work-check and its
    /// wait cannot miss the notification.
    pub fn wake_all(&self) {
        for shard in &self.shards {
            let _guard = shard.park.lock().unwrap();
            shard.cv.notify_all();
        }
    }

    /// Mark the queue shut down and wake every parked worker so they can
    /// observe it. Queued items are not drained; callers decide whether
    /// to finish or drop them.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.wake_all();
    }

    /// True once [`ShardedQueue::shutdown`] has been called.
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

/// The previous lock-per-shard queue (`Mutex<VecDeque>` + `Condvar` per
/// shard), kept verbatim as the baseline the `queue_contention_*` rows
/// in `benches/falkon_micro.rs` measure the lock-free ring against. Not
/// used by the service hot path.
pub struct MutexShardedQueue<T> {
    shards: Vec<MutexShard<T>>,
    len: AtomicUsize,
    peak: AtomicUsize,
    total_sleepers: AtomicUsize,
    rr: AtomicUsize,
    shutdown: AtomicBool,
}

struct MutexShard<T> {
    q: Mutex<VecDeque<T>>,
    cv: Condvar,
    sleepers: AtomicUsize,
}

impl<T> MutexShardedQueue<T> {
    // lint: allow(hot-path-alloc) — one-time construction, not dispatch
    pub fn new(nshards: usize) -> Self {
        let n = nshards.max(1);
        Self {
            shards: (0..n)
                .map(|_| MutexShard {
                    q: Mutex::new(VecDeque::new()),
                    cv: Condvar::new(),
                    sleepers: AtomicUsize::new(0),
                })
                .collect(),
            len: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            total_sleepers: AtomicUsize::new(0),
            rr: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        }
    }

    fn bump_peak(&self, candidate: usize) {
        // ord: monotone max over a gauge; no payload rides on this cell
        let mut cur = self.peak.load(Ordering::Relaxed);
        while candidate > cur {
            match self.peak.compare_exchange_weak(
                cur,
                candidate,
                // ord: monotone max over a gauge; publishes no payload
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }

    pub fn peak(&self) -> usize {
        // ord: gauge read; the writer side is Relaxed, so SeqCst here
        // never synchronized anything
        self.peak.load(Ordering::Relaxed)
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn len(&self) -> usize {
        self.len.load(Ordering::SeqCst)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn push(&self, item: T) {
        // ord: round-robin cursor; any distribution is correct
        let s = self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        let new_len;
        {
            let mut q = self.shards[s].q.lock().unwrap();
            q.push_back(item);
            new_len = self.len.fetch_add(1, Ordering::SeqCst) + 1;
        }
        self.bump_peak(new_len);
        self.wake(s, 1);
    }

    pub fn push_batch(&self, items: Vec<T>) {
        let k = items.len();
        if k == 0 {
            return;
        }
        let n = self.shards.len();
        // ord: round-robin cursor; any distribution is correct
        let start = self.rr.fetch_add(k, Ordering::Relaxed);
        let chunk = k.div_ceil(n);
        let mut items = items.into_iter();
        let mut pushed = 0usize;
        let mut i = 0usize;
        let mut max_len = 0usize;
        while pushed < k {
            let s = (start + i) % n;
            i += 1;
            let take = chunk.min(k - pushed);
            {
                let mut q = self.shards[s].q.lock().unwrap();
                for _ in 0..take {
                    q.push_back(items.next().expect("batch length"));
                }
                max_len = max_len.max(self.len.fetch_add(take, Ordering::SeqCst) + take);
            }
            self.wake(s, take);
            pushed += take;
        }
        self.bump_peak(max_len);
    }

    pub fn try_pop_batch(&self, home: usize, max: usize, out: &mut Vec<T>) -> usize {
        let n = self.shards.len();
        let home = home % n;
        for off in 0..n {
            let s = (home + off) % n;
            let mut q = self.shards[s].q.lock().unwrap();
            if q.is_empty() {
                continue;
            }
            let take = if off == 0 {
                q.len().min(max)
            } else {
                q.len().div_ceil(2).min(max)
            };
            for _ in 0..take {
                out.push(q.pop_front().expect("nonempty"));
            }
            self.len.fetch_sub(take, Ordering::SeqCst);
            return take;
        }
        0
    }

    pub fn park(&self, home: usize, timeout: Option<Duration>) -> bool {
        let shard = &self.shards[home % self.shards.len()];
        let mut q = shard.q.lock().unwrap();
        shard.sleepers.fetch_add(1, Ordering::SeqCst);
        self.total_sleepers.fetch_add(1, Ordering::SeqCst);
        let timed_out = if !q.is_empty()
            || self.len.load(Ordering::SeqCst) > 0
            || self.shutdown.load(Ordering::SeqCst)
        {
            false
        } else {
            match timeout {
                Some(t) => {
                    let (g, to) = shard
                        .cv
                        .wait_timeout(q, t)
                        .unwrap_or_else(|e| e.into_inner());
                    q = g;
                    to.timed_out()
                }
                None => {
                    q = shard.cv.wait(q).unwrap_or_else(|e| e.into_inner());
                    false
                }
            }
        };
        shard.sleepers.fetch_sub(1, Ordering::SeqCst);
        self.total_sleepers.fetch_sub(1, Ordering::SeqCst);
        drop(q);
        timed_out
    }

    fn wake(&self, preferred: usize, count: usize) {
        if self.total_sleepers.load(Ordering::SeqCst) == 0 {
            return;
        }
        let n = self.shards.len();
        let mut remaining = count;
        for off in 0..n {
            if remaining == 0 {
                return;
            }
            let shard = &self.shards[(preferred + off) % n];
            let guard = shard.q.lock().unwrap();
            let sleeping = shard.sleepers.load(Ordering::SeqCst);
            if sleeping == 0 {
                continue;
            }
            if remaining >= sleeping {
                shard.cv.notify_all();
            } else {
                for _ in 0..remaining {
                    shard.cv.notify_one();
                }
            }
            drop(guard);
            remaining = remaining.saturating_sub(sleeping);
        }
    }

    pub fn wake_all(&self) {
        for shard in &self.shards {
            let _guard = shard.q.lock().unwrap();
            shard.cv.notify_all();
        }
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.wake_all();
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The behavioral contract is pinned once and instantiated for both
    /// the lock-free queue and the Mutex baseline — they must stay
    /// interchangeable.
    macro_rules! queue_contract_suite {
        ($suite:ident, $Q:ident) => {
            mod $suite {
                use super::super::*;
                use std::sync::Arc;

                #[test]
                fn push_pop_roundtrip_across_shards() {
                    let q: $Q<u64> = $Q::new(4);
                    for i in 0..100 {
                        q.push(i);
                    }
                    assert_eq!(q.len(), 100);
                    let mut out = Vec::new();
                    let mut got = 0;
                    while q.try_pop_batch(0, 16, &mut out) > 0 {
                        got = out.len();
                    }
                    assert_eq!(got, 100);
                    let mut sorted = out.clone();
                    sorted.sort_unstable();
                    assert_eq!(sorted, (0..100).collect::<Vec<_>>());
                    assert!(q.is_empty());
                }

                #[test]
                fn batch_push_spreads_and_preserves_items() {
                    let q: $Q<u64> = $Q::new(3);
                    q.push_batch((0..31).collect());
                    assert_eq!(q.len(), 31);
                    let mut out = Vec::new();
                    while q.try_pop_batch(1, 8, &mut out) > 0 {}
                    let mut sorted = out;
                    sorted.sort_unstable();
                    assert_eq!(sorted, (0..31).collect::<Vec<_>>());
                }

                #[test]
                fn peak_tracks_high_water_mark() {
                    let q: $Q<u64> = $Q::new(4);
                    q.push_batch((0..10).collect());
                    let mut out = Vec::new();
                    while q.try_pop_batch(0, 64, &mut out) > 0 {}
                    assert!(q.is_empty());
                    q.push(99);
                    // Peak reflects the 10-deep burst, not the current
                    // length.
                    assert_eq!(q.peak(), 10);
                    assert_eq!(q.len(), 1);
                }

                #[test]
                fn steal_drains_other_shards() {
                    let q: $Q<u64> = $Q::new(4);
                    // All pushes land round-robin; pop everything from
                    // home shard 2 only via stealing.
                    for i in 0..40 {
                        q.push(i);
                    }
                    let mut out = Vec::new();
                    while q.try_pop_batch(2, 64, &mut out) > 0 {}
                    assert_eq!(out.len(), 40);
                }

                #[test]
                fn park_wakes_on_push() {
                    let q: Arc<$Q<u64>> = Arc::new($Q::new(2));
                    let q2 = Arc::clone(&q);
                    let h = std::thread::spawn(move || {
                        let mut out = Vec::new();
                        loop {
                            if q2.try_pop_batch(0, 4, &mut out) > 0 {
                                return out.len();
                            }
                            // A long timeout: the wakeup, not the timer,
                            // must end the wait (asserted by the elapsed
                            // bound below).
                            q2.park(0, Some(Duration::from_secs(10)));
                        }
                    });
                    std::thread::sleep(Duration::from_millis(20));
                    let t0 = std::time::Instant::now();
                    q.push(7);
                    assert_eq!(h.join().unwrap(), 1);
                    assert!(
                        t0.elapsed() < Duration::from_secs(2),
                        "push must wake the parked worker promptly"
                    );
                }

                #[test]
                fn cross_shard_push_wakes_parker() {
                    // Worker parks on shard 1; pushes land on shard 0
                    // first (rr cursor starts there). The wake scan must
                    // reach it.
                    let q: Arc<$Q<u64>> = Arc::new($Q::new(4));
                    let q2 = Arc::clone(&q);
                    let h = std::thread::spawn(move || {
                        let mut out = Vec::new();
                        loop {
                            if q2.try_pop_batch(1, 4, &mut out) > 0 {
                                return out[0];
                            }
                            q2.park(1, Some(Duration::from_secs(10)));
                        }
                    });
                    std::thread::sleep(Duration::from_millis(20));
                    let t0 = std::time::Instant::now();
                    q.push(42);
                    assert_eq!(h.join().unwrap(), 42);
                    assert!(t0.elapsed() < Duration::from_secs(2));
                }

                #[test]
                fn shutdown_unblocks_parkers() {
                    let q: Arc<$Q<u64>> = Arc::new($Q::new(2));
                    let q2 = Arc::clone(&q);
                    let h = std::thread::spawn(move || {
                        while !q2.is_shutdown() {
                            q2.park(1, Some(Duration::from_millis(100)));
                        }
                    });
                    std::thread::sleep(Duration::from_millis(10));
                    q.shutdown();
                    h.join().unwrap();
                }

                #[test]
                fn park_returns_immediately_when_work_exists() {
                    let q: $Q<u64> = $Q::new(2);
                    q.push(1);
                    // Work is on some shard; parking on any home must
                    // not block.
                    let t0 = std::time::Instant::now();
                    q.park(0, Some(Duration::from_secs(5)));
                    q.park(1, Some(Duration::from_secs(5)));
                    assert!(t0.elapsed() < Duration::from_millis(500));
                }
            }
        };
    }

    queue_contract_suite!(lockfree, ShardedQueue);
    queue_contract_suite!(mutex_baseline, MutexShardedQueue);

    #[test]
    fn ring_rejects_push_when_full_and_recovers() {
        let r: Ring<u64> = Ring::new(8);
        for i in 0..8 {
            assert!(r.push(i).is_ok());
        }
        assert_eq!(r.push(99), Err(99));
        assert_eq!(r.pop(), Some(0));
        assert!(r.push(8).is_ok());
        let rest: Vec<u64> = std::iter::from_fn(|| r.pop()).collect();
        assert_eq!(rest, (1..=8).collect::<Vec<_>>());
        assert_eq!(r.pop(), None);
    }

    #[test]
    fn overflow_spill_preserves_fifo_order() {
        // One shard, a burst deeper than the ring: items must spill to
        // the overflow and still drain in exact push order.
        let n = (RING_CAP + 500) as u64;
        let q: ShardedQueue<u64> = ShardedQueue::new(1);
        q.push_batch((0..n).collect());
        assert_eq!(q.len(), n as usize);
        let mut out = Vec::new();
        while q.try_pop_batch(0, 64, &mut out) > 0 {}
        assert_eq!(out, (0..n).collect::<Vec<_>>());
        assert!(q.is_empty());
        // Once the overflow drains, pushes return to the ring.
        q.push(7);
        assert_eq!(q.len(), 1);
        let mut out2 = Vec::new();
        assert_eq!(q.try_pop_batch(0, 4, &mut out2), 1);
        assert_eq!(out2, vec![7]);
    }

    #[test]
    fn concurrent_producers_consumers_conserve_items() {
        const PRODUCERS: usize = 4;
        const CONSUMERS: usize = 4;
        const PER_PRODUCER: u64 = 10_000;
        let q: std::sync::Arc<ShardedQueue<u64>> = std::sync::Arc::new(ShardedQueue::new(4));
        let producers: Vec<_> = (0..PRODUCERS as u64)
            .map(|p| {
                let q = std::sync::Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..PER_PRODUCER {
                        q.push(p * PER_PRODUCER + i);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..CONSUMERS)
            .map(|c| {
                let q = std::sync::Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    let deadline = std::time::Instant::now() + Duration::from_secs(30);
                    loop {
                        if q.try_pop_batch(c, DISPATCH_BATCH, &mut got) == 0 {
                            if q.is_shutdown() && q.is_empty() {
                                return got;
                            }
                            assert!(
                                std::time::Instant::now() < deadline,
                                "consumer starved"
                            );
                            q.park(c, Some(Duration::from_millis(50)));
                        }
                    }
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        // Let consumers finish the backlog, then release them.
        while !q.is_empty() {
            std::thread::sleep(Duration::from_millis(1));
        }
        q.shutdown();
        let mut all: Vec<u64> = Vec::new();
        for h in consumers {
            all.extend(h.join().unwrap());
        }
        all.sort_unstable();
        let expect: Vec<u64> = (0..PRODUCERS as u64 * PER_PRODUCER).collect();
        assert_eq!(all, expect, "every pushed item popped exactly once");
    }
}
