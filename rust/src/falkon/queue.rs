//! Sharded work queue with work stealing — the dispatch core's data
//! structure (paper §4: the "streamlined dispatcher").
//!
//! The seed implementation funneled every dispatch through one global
//! `Mutex<VecDeque>` + `Condvar`, serializing submitters against every
//! executor. This queue splits the deque into shards, each with its own
//! lock and condvar:
//!
//! - **Submitters** round-robin across shards (one lock per push;
//!   [`ShardedQueue::push_batch`] takes one lock per shard *per batch*).
//! - **Executors** drain their home shard in batches (one lock
//!   amortizes over up to `max` tasks) and **steal** half of another
//!   shard's backlog when their own is empty, so imbalance self-corrects.
//! - **Wakeups are targeted**: a push notifies sleepers on the receiving
//!   shard (falling back to any sleeping shard), never broadcasting to
//!   the whole pool — no thundering herd on single-task submits.
//!
//! The sleep/wake protocol is miss-free without polling: a parker
//! registers as a sleeper *before* checking for work (store→load), the
//! submit side publishes the new length *before* reading the sleeper
//! count (store→load), and both run under shard locks — so either the
//! parker sees the work and never sleeps, or the waker sees the sleeper
//! and notifies it. Idle workers therefore block indefinitely at zero
//! CPU cost; timeouts exist only as the DRP idle-deregistration clock.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Cap on queue shards. Tuned from `benches/falkon_micro.rs` (see
/// DESIGN.md §2.5): past 8 shards the per-shard locks are essentially
/// uncontended on the 4–16-executor pools the benches exercise, while
/// every additional shard lengthens the executor's empty-shard steal
/// scan and the submit side's wake scan. 8 is the knee.
pub const MAX_SHARDS: usize = 8;

/// Max tasks an executor pops per queue-lock acquisition. Tuned from
/// `benches/falkon_micro.rs` (see DESIGN.md §2.5): 32 amortizes the
/// shard lock to noise under backlog without letting one executor
/// monopolize a burst — the actual pop size is further capped at the
/// executor's fair share of the current backlog.
pub const DISPATCH_BATCH: usize = 32;

struct Shard<T> {
    q: Mutex<VecDeque<T>>,
    cv: Condvar,
    /// Workers currently blocked on `cv` (maintained inside the lock).
    sleepers: AtomicUsize,
}

/// A multi-shard MPMC work queue with batched operations and stealing.
pub struct ShardedQueue<T> {
    shards: Vec<Shard<T>>,
    /// Total queued items across shards (lock-free readers: DRP, stats).
    len: AtomicUsize,
    /// High-water mark of `len`, maintained exactly at push time.
    peak: AtomicUsize,
    /// Total sleepers across shards: lets the submit fast path skip the
    /// wake scan entirely when the pool is busy.
    total_sleepers: AtomicUsize,
    /// Round-robin submit cursor.
    rr: AtomicUsize,
    shutdown: AtomicBool,
}

impl<T> ShardedQueue<T> {
    pub fn new(nshards: usize) -> Self {
        let n = nshards.max(1);
        Self {
            shards: (0..n)
                .map(|_| Shard {
                    q: Mutex::new(VecDeque::new()),
                    cv: Condvar::new(),
                    sleepers: AtomicUsize::new(0),
                })
                .collect(),
            len: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            total_sleepers: AtomicUsize::new(0),
            rr: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        }
    }

    /// Monotonic CAS-max on the peak-length gauge.
    fn bump_peak(&self, candidate: usize) {
        let mut cur = self.peak.load(Ordering::Relaxed);
        while candidate > cur {
            match self.peak.compare_exchange_weak(
                cur,
                candidate,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }

    /// High-water mark of the queue length, exact as of each push.
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::SeqCst)
    }

    /// Number of shards (fixed at construction).
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total queued items across all shards (lock-free read).
    pub fn len(&self) -> usize {
        self.len.load(Ordering::SeqCst)
    }

    /// True when no shard holds work (lock-free read).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Push one item (one shard lock, one targeted wakeup).
    pub fn push(&self, item: T) {
        let s = self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        let new_len;
        {
            let mut q = self.shards[s].q.lock().unwrap();
            q.push_back(item);
            new_len = self.len.fetch_add(1, Ordering::SeqCst) + 1;
        }
        self.bump_peak(new_len);
        self.wake(s, 1);
    }

    /// Push a whole batch: items are spread round-robin in contiguous
    /// chunks, costing one lock acquisition and one wakeup per *shard*,
    /// not per task.
    pub fn push_batch(&self, items: Vec<T>) {
        let k = items.len();
        if k == 0 {
            return;
        }
        let n = self.shards.len();
        let start = self.rr.fetch_add(k, Ordering::Relaxed);
        let chunk = k.div_ceil(n);
        let mut items = items.into_iter();
        let mut pushed = 0usize;
        let mut i = 0usize;
        let mut max_len = 0usize;
        while pushed < k {
            let s = (start + i) % n;
            i += 1;
            let take = chunk.min(k - pushed);
            {
                let mut q = self.shards[s].q.lock().unwrap();
                for _ in 0..take {
                    q.push_back(items.next().expect("batch length"));
                }
                max_len = max_len.max(self.len.fetch_add(take, Ordering::SeqCst) + take);
            }
            self.wake(s, take);
            pushed += take;
        }
        self.bump_peak(max_len);
    }

    /// Pop up to `max` items into `out`, preferring the caller's home
    /// shard and stealing half of a sibling's backlog otherwise. Returns
    /// the number of items appended. Non-blocking.
    pub fn try_pop_batch(&self, home: usize, max: usize, out: &mut Vec<T>) -> usize {
        let n = self.shards.len();
        let home = home % n;
        for off in 0..n {
            let s = (home + off) % n;
            let mut q = self.shards[s].q.lock().unwrap();
            if q.is_empty() {
                continue;
            }
            // Home shard: take a full batch (FIFO). Sibling: steal half
            // so the owner keeps local work.
            let take = if off == 0 {
                q.len().min(max)
            } else {
                q.len().div_ceil(2).min(max)
            };
            for _ in 0..take {
                out.push(q.pop_front().expect("nonempty"));
            }
            self.len.fetch_sub(take, Ordering::SeqCst);
            return take;
        }
        0
    }

    /// Block on the home shard until a wakeup, the timeout (if any), or
    /// shutdown. Returns `true` if the wait timed out (the caller may
    /// then apply idle-deregistration policy). Returns immediately if
    /// work or shutdown is already visible.
    ///
    /// Miss-free protocol: the sleeper registers *before* re-checking
    /// for work, inside the shard lock. A concurrent submit publishes
    /// its length first and then scans sleeper counts under the same
    /// shard locks, so one side always sees the other.
    pub fn park(&self, home: usize, timeout: Option<Duration>) -> bool {
        let shard = &self.shards[home % self.shards.len()];
        let mut q = shard.q.lock().unwrap();
        shard.sleepers.fetch_add(1, Ordering::SeqCst);
        self.total_sleepers.fetch_add(1, Ordering::SeqCst);
        let timed_out = if !q.is_empty()
            || self.len.load(Ordering::SeqCst) > 0
            || self.shutdown.load(Ordering::SeqCst)
        {
            false
        } else {
            match timeout {
                Some(t) => {
                    let (g, to) = shard
                        .cv
                        .wait_timeout(q, t)
                        .unwrap_or_else(|e| e.into_inner());
                    q = g;
                    to.timed_out()
                }
                None => {
                    q = shard.cv.wait(q).unwrap_or_else(|e| e.into_inner());
                    false
                }
            }
        };
        shard.sleepers.fetch_sub(1, Ordering::SeqCst);
        self.total_sleepers.fetch_sub(1, Ordering::SeqCst);
        drop(q);
        timed_out
    }

    /// Wake up to `count` sleeping workers, preferring the shard that
    /// just received work and falling back to any shard with sleepers.
    /// Sleeper counts are read under each shard's lock, which pairs
    /// with `park`'s register-then-check to make wakeups miss-free; the
    /// `total_sleepers` fast path skips the scan when the pool is busy.
    fn wake(&self, preferred: usize, count: usize) {
        if self.total_sleepers.load(Ordering::SeqCst) == 0 {
            return;
        }
        let n = self.shards.len();
        let mut remaining = count;
        for off in 0..n {
            if remaining == 0 {
                return;
            }
            let shard = &self.shards[(preferred + off) % n];
            let guard = shard.q.lock().unwrap();
            let sleeping = shard.sleepers.load(Ordering::SeqCst);
            if sleeping == 0 {
                continue;
            }
            if remaining >= sleeping {
                shard.cv.notify_all();
            } else {
                for _ in 0..remaining {
                    shard.cv.notify_one();
                }
            }
            drop(guard);
            remaining = remaining.saturating_sub(sleeping);
        }
    }

    /// Wake every sleeping worker on every shard (shutdown/drain paths
    /// only — this is deliberately not used on the submit hot path).
    /// Locks each shard so a worker between its work-check and its wait
    /// cannot miss the notification.
    pub fn wake_all(&self) {
        for shard in &self.shards {
            let _guard = shard.q.lock().unwrap();
            shard.cv.notify_all();
        }
    }

    /// Mark the queue shut down and wake every parked worker so they can
    /// observe it. Queued items are not drained; callers decide whether
    /// to finish or drop them.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.wake_all();
    }

    /// True once [`ShardedQueue::shutdown`] has been called.
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_pop_roundtrip_across_shards() {
        let q: ShardedQueue<u64> = ShardedQueue::new(4);
        for i in 0..100 {
            q.push(i);
        }
        assert_eq!(q.len(), 100);
        let mut out = Vec::new();
        let mut got = 0;
        while q.try_pop_batch(0, 16, &mut out) > 0 {
            got = out.len();
        }
        assert_eq!(got, 100);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert!(q.is_empty());
    }

    #[test]
    fn batch_push_spreads_and_preserves_items() {
        let q: ShardedQueue<u64> = ShardedQueue::new(3);
        q.push_batch((0..31).collect());
        assert_eq!(q.len(), 31);
        let mut out = Vec::new();
        while q.try_pop_batch(1, 8, &mut out) > 0 {}
        let mut sorted = out;
        sorted.sort_unstable();
        assert_eq!(sorted, (0..31).collect::<Vec<_>>());
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        let q: ShardedQueue<u64> = ShardedQueue::new(4);
        q.push_batch((0..10).collect());
        let mut out = Vec::new();
        while q.try_pop_batch(0, 64, &mut out) > 0 {}
        assert!(q.is_empty());
        q.push(99);
        // Peak reflects the 10-deep burst, not the current length.
        assert_eq!(q.peak(), 10);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn steal_drains_other_shards() {
        let q: ShardedQueue<u64> = ShardedQueue::new(4);
        // All pushes land round-robin; pop everything from home shard 2
        // only via stealing.
        for i in 0..40 {
            q.push(i);
        }
        let mut out = Vec::new();
        while q.try_pop_batch(2, 64, &mut out) > 0 {}
        assert_eq!(out.len(), 40);
    }

    #[test]
    fn park_wakes_on_push() {
        let q: Arc<ShardedQueue<u64>> = Arc::new(ShardedQueue::new(2));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let mut out = Vec::new();
            loop {
                if q2.try_pop_batch(0, 4, &mut out) > 0 {
                    return out.len();
                }
                // A long timeout: the wakeup, not the timer, must end
                // the wait (asserted by the elapsed bound below).
                q2.park(0, Some(Duration::from_secs(10)));
            }
        });
        std::thread::sleep(Duration::from_millis(20));
        let t0 = std::time::Instant::now();
        q.push(7);
        assert_eq!(h.join().unwrap(), 1);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "push must wake the parked worker promptly"
        );
    }

    #[test]
    fn cross_shard_push_wakes_parker() {
        // Worker parks on shard 1; pushes land on shard 0 first (rr
        // cursor starts there). The wake scan must reach it.
        let q: Arc<ShardedQueue<u64>> = Arc::new(ShardedQueue::new(4));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let mut out = Vec::new();
            loop {
                if q2.try_pop_batch(1, 4, &mut out) > 0 {
                    return out[0];
                }
                q2.park(1, Some(Duration::from_secs(10)));
            }
        });
        std::thread::sleep(Duration::from_millis(20));
        let t0 = std::time::Instant::now();
        q.push(42);
        assert_eq!(h.join().unwrap(), 42);
        assert!(t0.elapsed() < Duration::from_secs(2));
    }

    #[test]
    fn shutdown_unblocks_parkers() {
        let q: Arc<ShardedQueue<u64>> = Arc::new(ShardedQueue::new(2));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            while !q2.is_shutdown() {
                q2.park(1, Some(Duration::from_millis(100)));
            }
        });
        std::thread::sleep(Duration::from_millis(10));
        q.shutdown();
        h.join().unwrap();
    }

    #[test]
    fn park_returns_immediately_when_work_exists() {
        let q: ShardedQueue<u64> = ShardedQueue::new(2);
        q.push(1);
        // Work is on some shard; parking on any home must not block.
        let t0 = std::time::Instant::now();
        q.park(0, Some(Duration::from_secs(5)));
        q.park(1, Some(Duration::from_secs(5)));
        assert!(t0.elapsed() < Duration::from_millis(500));
    }
}
