//! Falkon — the Fast and Light-weight tasK executiON framework (paper §4),
//! real-clock implementation.
//!
//! Falkon separates *resource provisioning* (acquiring executors) from
//! *task dispatch* (mapping queued tasks to acquired executors):
//!
//! - [`queue`] — the sharded, work-stealing service queue the dispatch
//!   core runs on (batched push/pop, targeted wakeups).
//! - [`service`] — the execution service: service queue, streamlined
//!   dispatcher, executor registry, DRP manager thread.
//! - [`provider`] — the Karajan [`crate::providers::Provider`] adapter
//!   ("the Falkon provider that we developed", §5.3).
//! - [`protocol`] — the client-facing network endpoint (the paper's
//!   WS-interface analogue): a TCP protocol with batched `SUBMITB`
//!   submit frames and coalesced `DONEB` acks, plus a client.
//!
//! The virtual-time Falkon *model* used for paper-scale experiments lives
//! in [`crate::sim::falkon_model`]; this module is the real data path the
//! end-to-end examples and throughput microbenchmarks exercise.

pub mod protocol;
pub mod provider;
pub mod queue;
pub mod service;

pub use protocol::{FalkonClient, FalkonTcpServer, RemoteResult, TaskSpec};
pub use provider::FalkonProvider;
pub use queue::{MutexShardedQueue, ShardedQueue};
pub use service::{FalkonService, FalkonServiceConfig, RealDrpPolicy, ServiceStats};
