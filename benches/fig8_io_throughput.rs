//! Figure 8: effect of task dispatch rates on achievable shared-FS I/O
//! throughput (GPFS, 8 I/O servers), for input sizes 1 B .. 1 GB on 64
//! nodes. Falkon's high dispatch rate reaches the FS's ideal throughput
//! at ~1 MB files; PBS/Condor need ~1 GB files to amortize their per-job
//! overhead.

use gridswift::metrics::plot::line_chart;
use gridswift::metrics::Table;
use gridswift::sim::driver::{Driver, Mode};
use gridswift::sim::falkon_model::{DrpPolicy, FalkonConfig};
use gridswift::sim::lrm::{GramConfig, LrmConfig};
use gridswift::sim::{Dag, SharedFs};

fn run(mode: Mode, bytes: u64, n: usize) -> f64 {
    let dag = Dag::io_bag(n, bytes, 0);
    let o = Driver::new(dag, mode, 7).with_shared_fs(SharedFs::gpfs_8()).run();
    // Achieved aggregate read throughput in MB/s.
    o.fs_bytes / o.makespan_secs / 1e6
}

fn falkon_mode() -> Mode {
    let mut cfg = FalkonConfig::default();
    cfg.drp = DrpPolicy::static_pool(64);
    cfg.drp.allocation_latency = 0;
    Mode::Falkon { cfg }
}

fn lrm_mode(lrm: LrmConfig) -> Mode {
    Mode::GramLrm {
        lrm,
        gram: GramConfig { submit_cost: 200_000, throttle_interval: 0 },
    }
}

fn main() {
    println!("== Figure 8: dispatch rate vs achievable GPFS I/O throughput ==");
    println!("(64 nodes, read-only tasks, GPFS = 8 x 125 MB/s, NIC cap 125 MB/s)\n");
    let sizes: [(u64, &str); 7] = [
        (1, "1B"),
        (1 << 10, "1KB"),
        (64 << 10, "64KB"),
        (1 << 20, "1MB"),
        (16 << 20, "16MB"),
        (256 << 20, "256MB"),
        (1 << 30, "1GB"),
    ];
    let ideal = 1000.0; // MB/s aggregate
    let mut t = Table::new(&["Input size", "Falkon MB/s", "PBS MB/s", "Condor MB/s", "ideal"]);
    let mut falkon_pts = Vec::new();
    let mut pbs_pts = Vec::new();
    for (bytes, label) in sizes {
        // Fewer tasks for giant files to keep sim fast; throughput is
        // steady-state either way.
        let n = if bytes >= (256 << 20) { 128 } else { 512 };
        let f = run(falkon_mode(), bytes, n);
        let p = run(lrm_mode(LrmConfig::pbs(32)), bytes, n);
        let c = run(lrm_mode(LrmConfig::condor(32)), bytes, n);
        falkon_pts.push((bytes as f64, f));
        pbs_pts.push((bytes as f64, p));
        t.row(&[
            label.to_string(),
            format!("{f:.1}"),
            format!("{p:.1}"),
            format!("{c:.1}"),
            format!("{ideal:.0}"),
        ]);
    }
    t.print();
    println!();
    print!(
        "{}",
        line_chart(
            "aggregate read MB/s vs input size (log x)",
            &[("Falkon", falkon_pts.clone()), ("PBS", pbs_pts.clone())],
            60,
            12,
            true,
        )
    );
    let f_1mb = falkon_pts[3].1;
    let p_1mb = pbs_pts[3].1;
    let p_1gb = pbs_pts[6].1;
    println!("\npaper shape checks:");
    println!(
        "  Falkon @1MB reaches {:.0}% of ideal (paper: close to ideal)",
        100.0 * f_1mb / ideal
    );
    println!(
        "  PBS @1MB reaches {:.0}% of ideal; needs ~1GB files ({:.0}%)",
        100.0 * p_1mb / ideal,
        100.0 * p_1gb / ideal
    );
}
