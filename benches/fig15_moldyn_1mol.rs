//! Figures 15/16: MolDyn 1-molecule run under DRP — the task view.
//!
//! Paper: the first job waits ~81 s (GRAM4+PBS allocation of the first
//! node); after the 3 serial prep jobs, a 68-wide fan-out triggers DRP to
//! allocate 31 more dual-processor nodes; the tail is serial again.

use gridswift::metrics::Table;
use gridswift::sim::driver::{Driver, Mode};
use gridswift::sim::falkon_model::{DrpPolicy, FalkonConfig};
use gridswift::sim::Dag;
use gridswift::util::time::secs;
use gridswift::util::DetRng;

fn main() {
    println!("== Figure 15/16: MolDyn 1-molecule task view (DRP) ==\n");
    let mut rng = DetRng::new(15);
    let dag = Dag::moldyn(1, &mut rng);
    println!("workflow: {} jobs (paper: 85)", dag.len());

    let mut cfg = FalkonConfig::default();
    cfg.drp = DrpPolicy {
        tasks_per_executor: 1,
        max_executors: 64,
        min_executors: 0,
        allocation_latency: secs(81.0),
        idle_timeout: secs(60.0),
        check_interval: secs(2.0),
        chunk: 2,
    };
    let o = Driver::new(dag, Mode::Falkon { cfg }, 15).run();

    let mut recs = o.timeline.records.clone();
    recs.sort_by_key(|r| r.started);
    let first = &recs[0];
    println!(
        "first job queue time: {:.0}s (paper: ~81s = first allocation)",
        first.wait() as f64 / 1e6
    );
    // Fan-out width: tasks running concurrently at the widest point.
    let mut events: Vec<(u64, i32)> = Vec::new();
    for r in &recs {
        events.push((r.started, 1));
        events.push((r.ended, -1));
    }
    events.sort();
    let mut cur = 0;
    let mut peak = 0;
    for (_, d) in events {
        cur += d;
        peak = peak.max(cur);
    }
    println!("peak concurrent tasks: {peak} (paper: 68-wide fan-out)");
    println!("peak executors provisioned: {} (paper: 32 nodes / 64 CPUs)", o.peak_resources);
    println!("makespan: {:.0}s", o.makespan_secs);
    println!(
        "speedup: {:.1}x (paper: 10.4x on up to 64 processors — serial stages dominate)",
        o.speedup(o.timeline.cpu_secs())
    );

    println!("\nper-stage view (queue wait vs exec):");
    let mut t = Table::new(&["Stage", "n", "avg wait", "avg exec"]);
    for (stage, rs) in o.timeline.by_stage() {
        let n = rs.len();
        let wait: f64 = rs.iter().map(|r| r.wait() as f64 / 1e6).sum::<f64>() / n as f64;
        let exec: f64 = rs.iter().map(|r| r.exec() as f64 / 1e6).sum::<f64>() / n as f64;
        t.row(&[
            stage,
            n.to_string(),
            format!("{wait:.0}s"),
            format!("{exec:.0}s"),
        ]);
    }
    t.print();
}
