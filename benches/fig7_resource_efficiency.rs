//! Figure 7: theoretical resource efficiency (1 M tasks) at three site
//! scales for varying dispatcher throughputs — the analytic model the
//! paper uses to motivate high dispatch rates.

use gridswift::metrics::plot::line_chart;
use gridswift::metrics::stats::dispatch_limited_efficiency;
use gridswift::metrics::Table;

fn main() {
    println!("== Figure 7: resource efficiency vs task length & throughput ==\n");
    let procs = [100.0, 1_000.0, 10_000.0];
    let throughputs = [1.0, 10.0, 100.0, 500.0, 1_000.0, 10_000.0, 100_000.0, 1_000_000.0];
    let lengths = [
        0.1, 0.2, 0.5, 1.0, 1.9, 5.0, 20.0, 100.0, 900.0, 10_000.0, 100_000.0,
    ];

    for &p in &procs {
        println!("--- {p:.0} processors ---");
        let mut t = Table::new(&[
            "Task len (s)",
            "1/s",
            "10/s",
            "100/s",
            "500/s",
            "1K/s",
            "10K/s",
            "100K/s",
            "1M/s",
        ]);
        for &len in &lengths {
            let mut row = vec![format!("{len}")];
            for &r in &throughputs {
                let e = dispatch_limited_efficiency(1e6, len, p, r);
                row.push(format!("{:.0}%", e * 100.0));
            }
            t.row(&row);
        }
        t.print();
        println!();
    }

    // Paper's headline sentences.
    let len_for_90 = |p: f64, r: f64| -> f64 {
        let mut lo: f64 = 1e-3;
        let mut hi = 1e6;
        for _ in 0..60 {
            let mid = (lo * hi).sqrt();
            if dispatch_limited_efficiency(1e6, mid, p, r) < 0.9 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        hi
    };
    println!("task length needed for 90% efficiency:");
    let mut t = Table::new(&["Procs", "@1 task/s", "@500 tasks/s"]);
    for (p, paper_lrm, paper_falkon) in [
        (100.0, "100 s", "0.2 s"),
        (1_000.0, "900 s", "1.9 s"),
        (10_000.0, "10000 s (~2.8 h)", "20 s"),
    ] {
        t.row(&[
            format!("{p:.0}"),
            format!("{:.1} s (paper: {paper_lrm})", len_for_90(p, 1.0)),
            format!("{:.2} s (paper: {paper_falkon})", len_for_90(p, 500.0)),
        ]);
    }
    t.print();

    let series: Vec<(&str, Vec<(f64, f64)>)> = vec![(
        "100 procs @1/s",
        lengths
            .iter()
            .map(|&l| (l, dispatch_limited_efficiency(1e6, l, 100.0, 1.0)))
            .collect(),
    ), (
        "100 procs @500/s",
        lengths
            .iter()
            .map(|&l| (l, dispatch_limited_efficiency(1e6, l, 100.0, 500.0)))
            .collect(),
    )];
    println!();
    print!("{}", line_chart("efficiency vs task length", &series, 60, 12, true));
}
