//! Figure 14: Montage workflow (3x3 degree mosaic of M16: ~440 plates,
//! ~2200 overlaps) under GRAM+clustering, Falkon, and MPI, 16 nodes.
//!
//! Paper: Falkon is close to MPI overall (and ~5% faster excluding the
//! final mAdd, which only the MPI version parallelized); GRAM+clustering
//! trails due to PBS queueing.

use gridswift::metrics::Table;
use gridswift::sim::driver::{Driver, Mode, SimOutcome};
use gridswift::sim::falkon_model::{DrpPolicy, FalkonConfig};
use gridswift::sim::lrm::{GramConfig, LrmConfig};
use gridswift::sim::Dag;
use gridswift::util::time::secs;
use gridswift::util::DetRng;

fn dag() -> Dag {
    let mut rng = DetRng::new(14);
    Dag::montage(440, 2200, 8, &mut rng)
}

fn per_stage(o: &SimOutcome) -> Vec<(String, f64)> {
    o.timeline
        .stage_windows()
        .into_iter()
        .map(|(s, a, b)| (s, b - a))
        .collect()
}

fn main() {
    println!("== Figure 14: Montage workflow execution time (16 nodes) ==\n");
    let cluster = Driver::new(
        dag(),
        Mode::GramCluster {
            lrm: LrmConfig::pbs(16),
            gram: GramConfig::gt2(),
            bundle: 64,
            window: secs(5.0),
        },
        2,
    )
    .run();
    let mut fcfg = FalkonConfig::default();
    fcfg.drp = DrpPolicy::static_pool(32); // 16 dual-proc nodes
    fcfg.drp.allocation_latency = 0;
    let falkon = Driver::new(dag(), Mode::Falkon { cfg: fcfg }, 2).run();
    let mpi = Driver::new(
        dag(),
        Mode::Mpi { procs: 32, stage_init: secs(3.0), stage_agg: secs(2.0) },
        2,
    )
    .run();

    // Per-stage table like the paper's figure.
    let fs = per_stage(&falkon);
    let cs = per_stage(&cluster);
    let ms = per_stage(&mpi);
    let mut t = Table::new(&["Stage", "GRAM+Clustering", "Falkon", "MPI"]);
    for (i, (stage, fdur)) in fs.iter().enumerate() {
        t.row(&[
            stage.clone(),
            format!("{:.0}s", cs.get(i).map(|x| x.1).unwrap_or(0.0)),
            format!("{fdur:.0}s"),
            format!("{:.0}s", ms.get(i).map(|x| x.1).unwrap_or(0.0)),
        ]);
    }
    t.row(&[
        "TOTAL".into(),
        format!("{:.0}s", cluster.makespan_secs),
        format!("{:.0}s", falkon.makespan_secs),
        format!("{:.0}s", mpi.makespan_secs),
    ]);
    t.print();

    println!("\npaper shape checks:");
    println!(
        "  Falkon/MPI total ratio: {:.2} (paper: close to 1.0)",
        falkon.makespan_secs / mpi.makespan_secs
    );
    // Excluding the final mAdd (parallelized only in MPI):
    let minus_madd = |o: &SimOutcome| {
        o.makespan_secs
            - per_stage(o)
                .iter()
                .find(|(s, _)| s == "mAdd(final)")
                .map(|x| x.1)
                .unwrap_or(0.0)
    };
    let f2 = minus_madd(&falkon);
    let m2 = minus_madd(&mpi);
    println!(
        "  excluding final mAdd: Falkon {f2:.0}s vs MPI {m2:.0}s ({:+.0}% — paper: Falkon ~5% faster)",
        (1.0 - f2 / m2) * 100.0
    );
    println!(
        "  GRAM+clustering trails Falkon by {:.1}x (paper: clustering did not match Falkon/MPI)",
        cluster.makespan_secs / falkon.makespan_secs
    );
}
