//! Figure 10: the pipelining effect for the fMRI workflow.
//!
//! The paper runs the 120-volume fMRI workflow (4 stages x 120 tasks)
//! with and without pipelining: staged execution waits for each whole
//! stage, so its makespan is sum_k(max_i t_ki); futures-driven pipelining
//! overlaps stages, bounded by max_i(sum_k t_ki). With the per-task
//! variance real shared clusters exhibit, the paper measured a 21%
//! reduction.
//!
//! Part 1 reproduces the paper's regime in virtual time (120 volumes,
//! seconds-scale tasks with realistic 0.7-1.5x variance, one processor
//! per volume as on TeraGrid). Part 2 demonstrates the same effect live
//! through the real engine (ms-scale sleeps).

use std::sync::Arc;

use gridswift::karajan::{Engine, EngineConfig, GridScheduler};
use gridswift::metrics::plot::gantt;
use gridswift::providers::{AppRunner, AppTask, LocalProvider, Provider};
use gridswift::sim::driver::{Driver, Mode};
use gridswift::sim::falkon_model::{DrpPolicy, FalkonConfig};
use gridswift::sim::{Dag, SimTask};
use gridswift::swiftscript::compile;
use gridswift::util::time::secs;
use gridswift::util::DetRng;

/// fMRI-shaped DAG with realistic shared-cluster variance (0.7-1.5x).
fn fmri_noisy(volumes: usize, seed: u64) -> Dag {
    let mut rng = DetRng::new(seed);
    let stages = ["reorient_y", "reorient_x", "alignlinear", "reslice"];
    let base = [3.0, 3.0, 5.0, 4.0];
    let mut dag = Dag::new();
    let mut prev: Vec<Option<usize>> = vec![None; volumes];
    for (k, stage) in stages.iter().enumerate() {
        for slot in prev.iter_mut() {
            // Shared-cluster service variance: broad jitter plus
            // occasional stragglers (NFS contention, slow nodes).
            let mut jitter = 0.7 + 0.8 * rng.f64();
            if rng.f64() < 0.06 {
                jitter *= 2.0;
            }
            let mut t = SimTask::new(stage, base[k] * jitter);
            if let Some(p) = *slot {
                t.deps = vec![p];
            }
            let id = dag.push(t);
            *slot = Some(id);
        }
    }
    dag
}

fn main() {
    println!("== Figure 10: pipelining effect, fMRI workflow ==\n");

    // ---- Part 1: paper regime (virtual time) ----
    let volumes = 120;
    let dag = fmri_noisy(volumes, 10);
    let mut cfg = FalkonConfig::default();
    cfg.drp = DrpPolicy::static_pool(volumes); // one processor per volume
    cfg.drp.allocation_latency = 0;
    let pipelined = Driver::new(dag.clone(), Mode::Falkon { cfg }, 10).run();
    // Staged baseline: strict barriers between stages, same processors.
    let staged = Driver::new(
        dag,
        Mode::Mpi { procs: volumes, stage_init: 0, stage_agg: 0 },
        10,
    )
    .run();
    println!("paper regime (120 volumes, 3-5s tasks, 0.7-1.5x variance):");
    println!(
        "  pipelined {:.1}s vs staged {:.1}s -> {:.0}% reduction (paper: 21%)",
        pipelined.makespan_secs,
        staged.makespan_secs,
        (1.0 - pipelined.makespan_secs / staged.makespan_secs) * 100.0
    );
    println!("\nstaged stage windows (distinct start times, paper top panel):");
    print!("{}", gantt("staged", &staged.timeline.stage_windows(), 48));
    println!("\npipelined stage windows (overlapped, paper bottom panel):");
    print!("{}", gantt("pipelined", &pipelined.timeline.stage_windows(), 48));

    // ---- Part 2: live demonstration through the real engine ----
    println!("\nlive engine demonstration (ms-scale):");
    let runner: AppRunner = Arc::new(|task: &AppTask| {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in task.args.join(" ").bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        std::thread::sleep(std::time::Duration::from_millis(10 + h % 50));
        for f in &task.outputs {
            if let Some(d) = f.parent() {
                std::fs::create_dir_all(d).ok();
            }
            std::fs::write(f, "x").ok();
        }
        Ok(())
    });
    let wd = std::env::temp_dir().join("gridswift_fig10");
    let _ = std::fs::remove_dir_all(&wd);
    let input = wd.join("in");
    std::fs::create_dir_all(&input).unwrap();
    for i in 0..32 {
        std::fs::write(input.join(format!("bold1_{i:04}.img")), "i").unwrap();
        std::fs::write(input.join(format!("bold1_{i:04}.hdr")), "h").unwrap();
    }
    let src = gridswift::apps::fmri::workflow_source(&input, &wd.join("out"), "bold1");
    let prog = compile(&src).unwrap();
    let mut times = Vec::new();
    for pipelining in [true, false] {
        let p: Arc<dyn Provider> =
            Arc::new(LocalProvider::new("site", 32, Arc::clone(&runner)));
        let sched = GridScheduler::new(vec![p], None, 0, 5);
        let engine = Engine::new(
            EngineConfig {
                workdir: wd.join(format!("work_{pipelining}")),
                pipelining,
                restart_log: None,
            },
            sched,
        );
        let t0 = std::time::Instant::now();
        let report = engine.run(&prog).unwrap();
        assert_eq!(report.executed, 128);
        times.push(t0.elapsed().as_secs_f64());
    }
    println!(
        "  real engine: pipelined {:.2}s vs staged {:.2}s ({:.0}% reduction)",
        times[0],
        times[1],
        (1.0 - times[0] / times[1]) * 100.0
    );
    let _ = secs(0.0);
}
