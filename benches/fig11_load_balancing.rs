//! Figure 11: load balancing across two clusters.
//!
//! 480 fMRI jobs submitted from UC_SUBMIT to both ANL_TG (62 dual-proc
//! IA64 nodes, slower) and UC_TP (120 dual-proc Opteron nodes, faster,
//! LAN-local). Paper: ANL_TG got 218 jobs, UC_TP 262, and the makespan
//! halved vs running on ANL_TG alone.

use gridswift::metrics::Table;
use gridswift::sim::driver::{Driver, Mode};
use gridswift::sim::lrm::{GramConfig, LrmConfig};
use gridswift::sim::Dag;
use gridswift::util::DetRng;

fn main() {
    println!("== Figure 11: load balancing across two clusters ==\n");
    let mut rng = DetRng::new(11);
    let dag = Dag::fmri(120, [8.0, 8.0, 10.0, 10.0], &mut rng);
    assert_eq!(dag.len(), 480, "120 volumes -> 480 jobs");

    // Two sites: ANL_TG uses its 62-node IA64 partition (speed 1.0);
    // UC_TP has 120 faster Opterons (2.2 GHz vs 1.3 GHz Itanium ~ 1.6x).
    let sites = vec![
        ("ANL_TG".to_string(), LrmConfig::pbs(62), 1.0),
        ("UC_TP".to_string(), LrmConfig::pbs(120), 1.6),
    ];
    let gram = GramConfig { submit_cost: 500_000, throttle_interval: 100_000 };
    let both = Driver::new(
        dag.clone(),
        Mode::MultiSite { sites, gram: gram.clone() },
        11,
    )
    .run();

    let single = Driver::new(
        dag.clone(),
        Mode::GramLrm { lrm: LrmConfig::pbs(62), gram },
        11,
    )
    .run();

    let counts = both.timeline.site_counts();
    let anl = counts.iter().find(|(s, _)| s == "ANL_TG").map(|x| x.1).unwrap_or(0);
    let uc = counts.iter().find(|(s, _)| s == "UC_TP").map(|x| x.1).unwrap_or(0);

    let mut t = Table::new(&["Metric", "Ours", "Paper"]);
    t.row(&["ANL_TG jobs".into(), anl.to_string(), "218".into()]);
    t.row(&["UC_TP jobs".into(), uc.to_string(), "262".into()]);
    t.row(&[
        "two-site makespan".into(),
        format!("{:.0}s", both.makespan_secs),
        "-".into(),
    ]);
    t.row(&[
        "single-site (ANL) makespan".into(),
        format!("{:.0}s", single.makespan_secs),
        "-".into(),
    ]);
    t.row(&[
        "reduction".into(),
        format!(
            "{:.0}%",
            (1.0 - both.makespan_secs / single.makespan_secs) * 100.0
        ),
        "~50%".into(),
    ]);
    t.print();

    println!("\nshape checks:");
    println!(
        "  faster site takes more work: UC_TP {uc} > ANL_TG {anl}  (paper: 262 > 218)"
    );
    println!(
        "  two sites cut the makespan by {:.0}% vs ANL alone (paper: ~50%)",
        (1.0 - both.makespan_secs / single.makespan_secs) * 100.0
    );
}
