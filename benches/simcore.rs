//! Sim-core raw speed (ROADMAP "Sim-core raw speed"): the discrete-event
//! engine is the substrate under every `sim_*` row in the other benches,
//! so this one measures the engine itself.
//!
//! Rows:
//!
//! - **queue churn** — a pure `EventQueue` microbench: a steady
//!   population of in-flight events scheduled at mixed horizons
//!   (same-instant storms, in-ring offsets, far-future overflow), popped
//!   in `(time, seq)` order. This isolates the calendar queue + payload
//!   slab from the rest of the driver; the acceptance bar is
//!   >= 1 M events/s.
//! - **1 M-task DAG** — end-to-end Falkon-mode run of `Dag::fmri`
//!   per-volume pipelines (250 k volumes x 4 stages) on a 1024-executor
//!   static pool: tasks/s, events/s, and peak RSS (VmHWM) for the whole
//!   build + simulate cycle.
//! - **telemetry overhead** — the same engine workload dark (global
//!   counters off, no span sink) vs fully lit (counters + a span sink
//!   sized for every lifecycle event), best-of-3 each; the lit run must
//!   stay within 5% of dark. A small spanned run is also exported as
//!   `TRACE_simcore.json` (Chrome-trace format) for the CI artifact.
//!
//! Flags: `--quick` shrinks both rows for CI; `--smoke` additionally
//! skips the JSON artifact and the throughput floor (used by the
//! debug-assertions CI smoke, where the engine runs with every
//! slab/handle/bitmap `debug_assert!` live).
//!
//! Both rows are deterministic virtual-time workloads, so CI gates the
//! `sim_*` keys (>20% regression fails) via `scripts/bench_trend.py`.

use std::time::Instant;

use gridswift::sim::driver::{Driver, Mode};
use gridswift::sim::falkon_model::{DrpPolicy, FalkonConfig};
use gridswift::sim::{Dag, Event, EventQueue};
use gridswift::telemetry::{counters, spans};
use gridswift::util::json::Json;
use gridswift::util::mem::vm_hwm_bytes;
use gridswift::util::DetRng;

/// In-flight event population for the queue microbench: enough to make
/// bucket reuse and overflow migration real, small enough to stay
/// cache-resident like the driver's steady state.
const CHURN_POPULATION: usize = 8192;

/// Pure queue churn: seed a population, then pop-one/push-one for
/// `total` events. Returns events per second.
fn queue_churn(total: u64) -> f64 {
    let mut q = EventQueue::new();
    let mut rng = DetRng::new(0x51C0);
    for i in 0..CHURN_POPULATION {
        q.after(rng.below(4096), Event::Release(i));
    }
    let t0 = Instant::now();
    let mut popped = 0u64;
    while popped < total {
        let (_, ev) = q.pop().expect("population never drains");
        popped += 1;
        // Re-schedule at a mixed horizon: ~1/2 same-instant or near
        // (storms), ~3/8 spread across the ring, ~1/8 far-future
        // (overflow heap), mirroring the driver's mix of dispatch
        // storms, service completions, and DRP timeouts.
        let d = match rng.below(8) {
            0..=3 => rng.below(4),
            4..=6 => rng.below(4000),
            _ => 4096 + rng.below(100_000),
        };
        q.after(d, ev);
    }
    popped as f64 / t0.elapsed().as_secs_f64()
}

/// End-to-end DAG run: build the fMRI pipeline DAG and drive it through
/// the Falkon-mode sim. Returns (tasks/s, events/s, n_tasks, events).
fn dag_run(volumes: usize) -> (f64, f64, usize, u64) {
    let mut rng = DetRng::new(0x51C1);
    let t0 = Instant::now();
    let dag = Dag::fmri(volumes, [1.0, 1.0, 1.0, 1.0], &mut rng);
    let n = dag.len();
    let mut cfg = FalkonConfig::default();
    cfg.drp = DrpPolicy::static_pool(1024);
    cfg.drp.allocation_latency = 0;
    let o = Driver::new(dag, Mode::Falkon { cfg }, 0x51C1).run();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(o.timeline.len(), n, "every task completes");
    (n as f64 / wall, o.events as f64 / wall, n, o.events)
}

/// Build the standard Falkon-mode fMRI driver for `volumes` volumes.
fn fmri_driver(volumes: usize, seed: u64) -> (Driver, usize) {
    let mut rng = DetRng::new(seed);
    let dag = Dag::fmri(volumes, [1.0, 1.0, 1.0, 1.0], &mut rng);
    let n = dag.len();
    let mut cfg = FalkonConfig::default();
    cfg.drp = DrpPolicy::static_pool(1024);
    cfg.drp.allocation_latency = 0;
    (Driver::new(dag, Mode::Falkon { cfg }, seed), n)
}

/// One telemetry-probe run: the same engine workload dark (global
/// counters off, no span sink) or fully lit (counters on + a span sink
/// sized for every lifecycle event). Returns events/s.
fn telemetry_run(volumes: usize, lit: bool) -> f64 {
    counters::set_enabled(lit);
    let (mut driver, n) = fmri_driver(volumes, 0x51C2);
    if lit {
        driver = driver.with_spans(8 * n);
    }
    let t0 = Instant::now();
    let o = driver.run();
    let eps = o.events as f64 / t0.elapsed().as_secs_f64();
    counters::set_enabled(true);
    assert_eq!(o.timeline.len(), n, "every task completes");
    std::hint::black_box(o.span_events.len());
    eps
}

/// Best-of-3 wrapper (thermal/scheduler noise hurts, never helps).
fn best_of_3(mut f: impl FnMut() -> f64) -> f64 {
    (0..3).map(|_| f()).fold(0.0f64, f64::max)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let smoke = std::env::args().any(|a| a == "--smoke");

    let churn_total: u64 = if quick { 500_000 } else { 4_000_000 };
    // 4 stages per volume: 250 k volumes = the 1 M-task trace.
    let volumes = if quick { 25_000 } else { 250_000 };

    println!("== Sim-core raw speed ==\n");

    let queue_eps = queue_churn(churn_total);
    println!(
        "queue churn:   {:>10.0} events/s ({churn_total} events, \
         {CHURN_POPULATION} in flight)",
        queue_eps
    );

    let (tasks_per_s, events_per_s, n_tasks, events) = dag_run(volumes);
    let peak_rss_mb =
        vm_hwm_bytes().unwrap_or(0) as f64 / (1024.0 * 1024.0);
    println!(
        "{n_tasks}-task DAG: {:>10.0} tasks/s, {:>10.0} events/s \
         ({events} events), peak RSS {:.0} MB",
        tasks_per_s, events_per_s, peak_rss_mb
    );

    // Telemetry overhead: same workload, dark vs fully lit.
    let tele_volumes = if quick { 5_000 } else { 20_000 };
    let dark_eps = best_of_3(|| telemetry_run(tele_volumes, false));
    let lit_eps = best_of_3(|| telemetry_run(tele_volumes, true));
    let overhead_pct = (1.0 - lit_eps / dark_eps) * 100.0;
    println!(
        "telemetry:     {lit_eps:>10.0} events/s lit vs {dark_eps:>10.0} \
         dark ({overhead_pct:+.1}% overhead)"
    );

    // Chrome-trace artifact: a small spanned run, uploadable by CI and
    // openable in Perfetto / about:tracing.
    {
        let (driver, n) = fmri_driver(200, 0x51C3);
        let o = driver.with_spans(8 * n).run();
        let tasks = spans::assemble(&o.span_events);
        assert_eq!(tasks.len(), n, "one lifecycle per task");
        assert!(
            tasks.iter().all(|t| t.complete() && t.ordered()),
            "every simulated task records all six stages in order"
        );
        std::fs::write("TRACE_simcore.json", spans::chrome_trace(&tasks).render())
            .expect("write TRACE_simcore.json");
        println!("wrote TRACE_simcore.json ({} task tracks)", tasks.len());
    }

    if !smoke {
        // The acceptance bar from the issue: the bare engine must
        // sustain a million events per second.
        assert!(
            queue_eps >= 1e6,
            "queue microbench below 1 M events/s: {queue_eps:.0}"
        );
        // Telemetry acceptance: fully lit within 5% of dark.
        assert!(
            overhead_pct < 5.0,
            "telemetry overhead {overhead_pct:.1}% exceeds the 5% budget \
             ({lit_eps:.0} lit vs {dark_eps:.0} dark events/s)"
        );

        let mut report = Json::obj();
        report.set("bench", "simcore");
        report.set("quick", quick);
        report.set("churn_events", churn_total);
        report.set("n_tasks", n_tasks as u64);
        report.set("dag_events", events);
        report.set("sim_queue_events_per_s", queue_eps);
        report.set("sim_dag_tasks_per_s", tasks_per_s);
        report.set("sim_dag_events_per_s", events_per_s);
        report.set("telemetry_churn_events_per_s", lit_eps);
        report.set("telemetry_overhead_pct", overhead_pct);
        report.set("peak_rss_mb", peak_rss_mb);
        std::fs::write("BENCH_simcore.json", report.render())
            .expect("write BENCH_simcore.json");
        println!("\nwrote BENCH_simcore.json");
    }
}
