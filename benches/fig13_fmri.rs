//! Figure 13: fMRI workflow execution time for growing input sizes under
//! GRAM+PBS per-task submission, GRAM+clustering, and Falkon (8 nodes).
//!
//! Task service times are calibrated from real kernel execution when
//! artifacts are present (one reorient/alignlinear/reslice measured via
//! PJRT); otherwise the paper's "a few seconds" defaults apply. The
//! comparison itself runs in virtual time (a GRAM+PBS 480-volume run
//! takes hours of simulated time).

use gridswift::metrics::plot::bar_chart;
use gridswift::metrics::Table;
use gridswift::runtime::{self, Tensor};
use gridswift::sim::driver::{Driver, Mode};
use gridswift::sim::falkon_model::{DrpPolicy, FalkonConfig};
use gridswift::sim::lrm::{GramConfig, LrmConfig};
use gridswift::sim::Dag;
use gridswift::util::time::secs;
use gridswift::util::DetRng;

/// Measure real per-stage kernel times (seconds) if artifacts exist.
fn calibrate() -> [f64; 4] {
    let dir = runtime::default_artifact_dir();
    if !dir.join("manifest.txt").exists() || runtime::init(dir).is_err() {
        println!("(artifacts missing: using paper-style 3-5s defaults)\n");
        return [3.0, 3.0, 5.0, 4.0];
    }
    let vol = Tensor::new(
        vec![64, 64, 24],
        (0..64 * 64 * 24).map(|i| (i % 17) as f32).collect(),
    );
    let time_of = |name: &str, inputs: &[Tensor]| -> f64 {
        runtime::execute(name, inputs).unwrap(); // warm (compile)
        let t0 = std::time::Instant::now();
        runtime::execute(name, inputs).unwrap();
        t0.elapsed().as_secs_f64()
    };
    let r = time_of("reorient_y", std::slice::from_ref(&vol));
    let a = time_of("alignlinear", &[vol.clone(), vol.clone()]);
    let params = Tensor::vec(vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0]);
    let s = time_of("reslice", &[vol, params]);
    // The 2007 Itanium ran these in seconds; our kernels are faster, so
    // report both and scale to the paper's regime for the queueing sim
    // (the *ratios* between systems are overhead-dominated, not
    // compute-dominated).
    println!(
        "calibrated kernel times: reorient {r:.3}s, alignlinear {a:.3}s, reslice {s:.3}s"
    );
    let scale = 3.0 / r.max(1e-4);
    println!(
        "scaling by {scale:.0}x to the paper's ANL_TG regime (reorient ~ 3s)\n"
    );
    [r * scale, r * scale, a * scale, s * scale]
}

fn main() {
    println!("== Figure 13: fMRI workflow execution time ==\n");
    let stage_secs = calibrate();
    let volume_counts = [120usize, 240, 360, 480];
    let mut t = Table::new(&[
        "Volumes",
        "Jobs",
        "GRAM+PBS",
        "GRAM+Clustering",
        "Falkon(8 nodes)",
        "reduction",
    ]);
    let mut bars = Vec::new();
    for &v in &volume_counts {
        let mk = || {
            let mut rng = DetRng::new(13);
            Dag::fmri(v, stage_secs, &mut rng)
        };
        let gram = Driver::new(
            mk(),
            Mode::GramLrm { lrm: LrmConfig::pbs(62), gram: GramConfig::gt2() },
            1,
        )
        .run();
        // Bundle into ~8 groups per stage wave (paper: jobs bundled into
        // roughly 8 groups).
        let cluster = Driver::new(
            mk(),
            Mode::GramCluster {
                lrm: LrmConfig::pbs(62),
                gram: GramConfig::gt2(),
                bundle: v / 8,
                window: secs(5.0),
            },
            1,
        )
        .run();
        let mut fcfg = FalkonConfig::default();
        fcfg.drp = DrpPolicy::static_pool(16); // 8 dual-proc nodes
        fcfg.drp.allocation_latency = 0;
        let falkon = Driver::new(mk(), Mode::Falkon { cfg: fcfg }, 1).run();
        let red = (1.0 - falkon.makespan_secs / gram.makespan_secs) * 100.0;
        t.row(&[
            v.to_string(),
            (4 * v).to_string(),
            format!("{:.0}s", gram.makespan_secs),
            format!("{:.0}s", cluster.makespan_secs),
            format!("{:.0}s", falkon.makespan_secs),
            format!("{red:.0}%"),
        ]);
        if v == 120 {
            bars.push(("GRAM+PBS".to_string(), gram.makespan_secs));
            bars.push(("GRAM+Clustering".to_string(), cluster.makespan_secs));
            bars.push(("Falkon".to_string(), falkon.makespan_secs));
        }
    }
    t.print();
    println!();
    print!("{}", bar_chart("120-volume makespan (s)", &bars, 44));
    println!("\npaper shape checks:");
    println!("  clustering improves GRAM by 2-4x; Falkon reduces GRAM time by up to 90%");
    let g = bars[0].1;
    let c = bars[1].1;
    let f = bars[2].1;
    println!(
        "  ours @120 volumes: clustering {:.1}x, Falkon {:.0}% reduction",
        g / c,
        (1.0 - f / g) * 100.0
    );
}
