//! Table 1 + §3.7: lines of code across workflow encodings.
//!
//! The paper compares ad-hoc shell scripts, PERL DAG generators, and
//! SwiftScript. We bundle genuine encodings under `workflows/` (all five
//! fMRI workflows in SwiftScript — each verified to compile with this
//! repository's compiler — plus full script+generator encodings of the
//! smallest and largest workflows) and count non-blank, non-comment lines
//! exactly as the paper did. Paper numbers are printed alongside for the
//! shape comparison.

use gridswift::metrics::Table;
use gridswift::swiftscript::compile;
use gridswift::util::loc::count_file_loc;
use std::path::Path;

fn loc(file: &str, comments: &[&str]) -> String {
    let p = Path::new("workflows").join(file);
    match count_file_loc(&p, comments) {
        Ok(n) => n.to_string(),
        Err(_) => "-".into(),
    }
}

fn main() {
    println!("== Table 1: Lines of Code with Different Workflow Encodings ==\n");
    // (workflow, paper script, paper generator, paper swift, our files)
    let rows = [
        ("GENATLAS1", 49, 72, 6, "genatlas1"),
        ("GENATLAS2", 97, 135, 10, "genatlas2"),
        ("FILM1", 63, 134, 17, "film1"),
        ("FEAT", 84, 191, 13, "feat"),
        ("AIRSN", 215, 400, 37, "airsn"),
    ];
    let mut t = Table::new(&[
        "Workflow",
        "Script(paper)",
        "Script(ours)",
        "Generator(paper)",
        "Generator(ours)",
        "Swift(paper)",
        "Swift(ours)",
    ]);
    for (name, ps, pg, pw, stem) in rows {
        t.row(&[
            name.to_string(),
            ps.to_string(),
            loc(&format!("{stem}.sh"), &["#"]),
            format!("~{pg}"),
            loc(&format!("{stem}_gen.pl"), &["#"]),
            pw.to_string(),
            loc(&format!("{stem}.swift"), &["//"]),
        ]);
    }
    t.print();

    // Verify every bundled SwiftScript workflow compiles with our
    // compiler (conciseness without loss of checkability).
    println!("\ncompile check (our SwiftScript encodings):");
    for stem in ["genatlas1", "genatlas2", "film1", "feat", "airsn"] {
        let p = Path::new("workflows").join(format!("{stem}.swift"));
        let src = std::fs::read_to_string(&p).expect("read workflow");
        match compile(&src) {
            Ok(tp) => println!("  {stem:<10} OK ({} procedures)", tp.procs.len()),
            Err(e) => println!("  {stem:<10} FAILED: {e:#}"),
        }
    }

    println!("\n== §3.7: Montage parallelization ==");
    let mut t2 = Table::new(&["Encoding", "LoC"]);
    t2.row(&["MPI (mProjExecMPI, C++, paper)".into(), "950".into()]);
    t2.row(&["SwiftScript batch (paper)".into(), "15".into()]);
    // Our full dynamic montage workflow (apps::montage::workflow_source)
    // including all six stages:
    let src = gridswift::apps::montage::workflow_source(
        Path::new("/survey"),
        Path::new("/out"),
    );
    let our = gridswift::util::loc::count_loc(&src, &["//"]);
    t2.row(&["SwiftScript full montage (ours)".into(), our.to_string()]);
    t2.print();
    println!(
        "\nShape check: SwiftScript is one order of magnitude smaller than \
         script/generator/MPI encodings, as the paper reports."
    );
}
