//! Figure 12: Swift throughput with the Falkon provider — sleep(0) jobs
//! per second for (a) a Falkon client submitting directly, (b) a client
//! over TCP line-per-task and (b') over batched SUBMITB frames (the
//! paper's LAN/WAN hops, with and without the batched wire protocol),
//! (c) Swift submitting through the Falkon provider (full engine path:
//! site selection, sandbox dirs, logging, streamed batch submits),
//! (d) the GRAM+PBS baseline (simulated: ~2 jobs/s), and (e) a
//! virtual-time WAN variant with nonzero `FrameConfig` costs: the same
//! bag submitted framed (cap 256 via the shared `FrameCoalescer`
//! cut-off) vs line-per-task over a paper-scale WAN round trip, both
//! through the sim's serialized submit channel.
//!
//! Paper: Falkon direct ~120/s, Swift+Falkon 56/s LAN, 46/s WAN,
//! GT2 GRAM+PBS ~2/s (Swift+Falkon = 23x GRAM).

use std::sync::Arc;
use std::time::Instant;

use gridswift::apps::AppRegistry;
use gridswift::falkon::{
    FalkonClient, FalkonService, FalkonServiceConfig, FalkonTcpServer, RealDrpPolicy,
    TaskSpec,
};
use gridswift::metrics::Table;
use gridswift::util::json::Json;
use gridswift::providers::AppTask;
use gridswift::sim::driver::{Driver, Mode};
use gridswift::sim::falkon_model::{DrpPolicy, FalkonConfig, FrameConfig, WireFormat};
use gridswift::sim::lrm::{GramConfig, LrmConfig};
use gridswift::sim::Dag;
use gridswift::stack::{build, ProviderKind, StackOptions};
use gridswift::swiftscript::compile;
use gridswift::telemetry::counters;
use gridswift::util::mem::vm_hwm_bytes;

fn service(workers: usize) -> Arc<FalkonService> {
    FalkonService::start(
        FalkonServiceConfig {
            drp: RealDrpPolicy::static_pool(workers),
            executor_overhead: std::time::Duration::ZERO,
        },
        Arc::new(AppRegistry::standard()).runner(),
    )
}

fn direct_inproc(n: u64) -> f64 {
    let svc = service(8);
    let (tx, rx) = std::sync::mpsc::channel();
    let t0 = Instant::now();
    for i in 0..n {
        let tx = tx.clone();
        svc.submit(
            AppTask {
                id: i,
                key: format!("k{i}"),
                executable: "sleep0".into(),
                args: vec![],
                inputs: vec![],
                outputs: vec![],
            },
            Box::new(move |r| {
                let _ = tx.send(r.ok);
            }),
        );
    }
    for _ in 0..n {
        rx.recv().unwrap();
    }
    n as f64 / t0.elapsed().as_secs_f64()
}

fn direct_tcp(n: u64) -> f64 {
    let svc = service(8);
    let server = FalkonTcpServer::start(Arc::clone(&svc), "127.0.0.1:0").unwrap();
    let mut client = FalkonClient::connect(server.addr()).unwrap();
    let t0 = Instant::now();
    for i in 0..n {
        client.submit(i, "sleep0", &[]).unwrap();
    }
    for _ in 0..n {
        client.next_result().unwrap();
    }
    n as f64 / t0.elapsed().as_secs_f64()
}

/// The batched wire path: SUBMITB frames of `chunk` tasks (one write +
/// one server-side queue push per frame) with coalesced DONEB acks.
/// `binary` negotiates wire grammar v2 (length-prefixed frames) instead
/// of the legacy text lines.
fn framed_tcp(n: u64, chunk: u64, binary: bool) -> f64 {
    let svc = service(8);
    let server = FalkonTcpServer::start(Arc::clone(&svc), "127.0.0.1:0").unwrap();
    let mut client = if binary {
        FalkonClient::connect_binary(server.addr()).unwrap()
    } else {
        FalkonClient::connect(server.addr()).unwrap()
    };
    let t0 = Instant::now();
    let mut i = 0u64;
    while i < n {
        let hi = (i + chunk).min(n);
        let frame: Vec<TaskSpec> = (i..hi)
            .map(|id| TaskSpec { id, executable: "sleep0".into(), args: vec![] })
            .collect();
        client.submit_batch(&frame).unwrap();
        i = hi;
    }
    for _ in 0..n {
        client.next_result().unwrap();
    }
    n as f64 / t0.elapsed().as_secs_f64()
}

fn via_swift(n: usize) -> f64 {
    // A SwiftScript bag of sleep0 tasks through the whole stack.
    let wd = std::env::temp_dir().join("gridswift_fig12");
    let _ = std::fs::remove_dir_all(&wd);
    std::fs::create_dir_all(&wd).unwrap();
    for i in 0..n {
        std::fs::write(wd.join(format!("t_{i}.dat")), "x").unwrap();
    }
    let src = format!(
        r#"
type F {{}};
(F o) noop (F i) {{ app {{ sleep0 @filename(i) @filename(o); }} }}
F inputs[]<array_mapper;location="{}",prefix="t_",suffix=".dat">;
F outs[];
foreach f, i in inputs {{
  outs[i] = noop(f);
}}
"#,
        wd.display()
    );
    let prog = compile(&src).unwrap();
    let stack = build(StackOptions {
        provider: ProviderKind::Falkon,
        workers: 8,
        workdir: wd.join("work"),
        retries: 0,
        ..Default::default()
    })
    .unwrap();
    let t0 = Instant::now();
    let report = stack.engine.run(&prog).unwrap();
    assert_eq!(report.executed as usize, n);
    n as f64 / t0.elapsed().as_secs_f64()
}

/// Per-frame WAN submit round trip (UC->ANL scale, ~20 ms) and per-task
/// line cost inside a frame.
const WAN_RTT_US: u64 = 20_000;
const WAN_PER_TASK_US: u64 = 100;

/// Virtual-time WAN submission: a sleep(0)-scale bag through the sim's
/// Falkon model with costed framing. `frame_cap` 1 models the legacy
/// line-per-task client (every task pays the full round trip,
/// serialized on the submit channel); larger caps model the batched
/// `SUBMITB` client, whose cut-off is the same `FrameCoalescer` policy
/// the real client ships.
fn sim_wan(n: usize, frame_cap: usize, wire: WireFormat) -> f64 {
    let mut cfg = FalkonConfig::default();
    cfg.drp = DrpPolicy::static_pool(8);
    cfg.drp.allocation_latency = 0;
    cfg.executor_overhead = 0;
    cfg.framing = FrameConfig {
        frame_cap,
        frame_overhead: WAN_RTT_US,
        per_task_cost: WAN_PER_TASK_US,
        wire,
    };
    let dag = Dag::bag(n, "sleep0", 0.001);
    let o = Driver::new(dag, Mode::Falkon { cfg }, 17).run();
    n as f64 / o.makespan_secs
}

fn gram_pbs_sim(n: usize) -> f64 {
    let dag = Dag::bag(n, "sleep0", 0.01);
    // The paper's "standard setting" (GT2 GRAM + PBS, no MolDyn-style
    // 5-second throttle): up to ~2 jobs/s.
    let o = Driver::new(
        dag,
        Mode::GramLrm {
            lrm: LrmConfig::pbs(32),
            gram: GramConfig { submit_cost: 300_000, throttle_interval: 200_000 },
        },
        3,
    )
    .run();
    n as f64 / o.makespan_secs
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    println!("== Figure 12: Swift/Falkon sleep(0) throughput ==\n");
    let (n_direct, n_swift, n_gram) =
        if quick { (5_000, 1_000, 200) } else { (20_000, 4_000, 500) };
    let inproc = direct_inproc(n_direct);
    let tcp = direct_tcp(n_direct);
    let tcp_framed = framed_tcp(n_direct, 256, false);
    let tcp_binary = framed_tcp(n_direct, 256, true);
    let swift = via_swift(n_swift);
    let gram = gram_pbs_sim(n_gram);
    // Virtual-time WAN variant (deterministic; same n in both modes).
    let n_wan = if quick { 1_500 } else { 5_000 };
    let wan_framed = sim_wan(n_wan, 256, WireFormat::Text);
    let wan_line = sim_wan(n_wan, 1, WireFormat::Text);
    let wan_binary = sim_wan(n_wan, 256, WireFormat::Binary);

    let mut t = Table::new(&["Path", "tasks/s (ours)", "paper"]);
    t.row(&[
        "Falkon client, in-process".into(),
        format!("{inproc:.0}"),
        "120 (ANL->ANL)".into(),
    ]);
    t.row(&[
        "Falkon client, TCP line-per-task".into(),
        format!("{tcp:.0}"),
        "~115 (UC->ANL)".into(),
    ]);
    t.row(&[
        "Falkon client, TCP SUBMITB x256".into(),
        format!("{tcp_framed:.0}"),
        "- (batched frames)".into(),
    ]);
    t.row(&[
        "Falkon client, TCP binary x256".into(),
        format!("{tcp_binary:.0}"),
        "- (wire grammar v2)".into(),
    ]);
    t.row(&[
        "Swift -> Falkon provider".into(),
        format!("{swift:.0}"),
        "56 (LAN) / 46 (WAN)".into(),
    ]);
    t.row(&[
        "GT2 GRAM + PBS (simulated)".into(),
        format!("{gram:.1}"),
        "~2".into(),
    ]);
    t.row(&[
        "WAN sim, line-per-task (20ms RTT)".into(),
        format!("{wan_line:.0}"),
        "~46-115 (UC->ANL)".into(),
    ]);
    t.row(&[
        "WAN sim, SUBMITB x256 (20ms RTT)".into(),
        format!("{wan_framed:.0}"),
        "- (batched frames)".into(),
    ]);
    t.row(&[
        "WAN sim, binary x256 (20ms RTT)".into(),
        format!("{wan_binary:.0}"),
        "- (wire grammar v2)".into(),
    ]);
    t.print();

    println!("\nshape checks:");
    println!(
        "  framed TCP vs line-per-task TCP: {:.1}x (batched frames cut per-task round trips)",
        tcp_framed / tcp
    );
    println!(
        "  WAN sim framed vs line-per-task: {:.1}x (wire-bound ~{:.0}/s -> dispatcher-bound)",
        wan_framed / wan_line,
        1e6 / (WAN_RTT_US + WAN_PER_TASK_US) as f64
    );
    println!(
        "  Swift adds engine overhead vs direct submission: {:.1}x slower (paper: ~2.1x)",
        inproc / swift
    );
    println!(
        "  Swift+Falkon vs GRAM+PBS: {:.0}x faster (paper: 23x)",
        swift / gram
    );

    // Machine-readable dump for regression tracking across PRs.
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let mut report = Json::obj();
    report.set("bench", "fig12_throughput");
    report.set("cores", cores);
    report.set("quick", quick);
    report.set("n_direct", n_direct);
    report.set("n_swift", n_swift);
    report.set("n_gram", n_gram);
    report.set("falkon_inproc_tasks_per_s", inproc);
    report.set("falkon_tcp_tasks_per_s", tcp);
    report.set("falkon_tcp_framed_tasks_per_s", tcp_framed);
    report.set("falkon_tcp_binary_tasks_per_s", tcp_binary);
    report.set("falkon_tcp_frame_chunk", 256u64);
    report.set("swift_falkon_tasks_per_s", swift);
    report.set("gram_pbs_sim_tasks_per_s", gram);
    report.set("n_wan", n_wan);
    report.set("sim_wan_rtt_us", WAN_RTT_US);
    report.set("sim_wan_per_task_us", WAN_PER_TASK_US);
    report.set("sim_wan_framed_tasks_per_s", wan_framed);
    report.set("sim_wan_line_per_task_tasks_per_s", wan_line);
    report.set("sim_wan_binary_tasks_per_s", wan_binary);
    report.set("paper_falkon_direct_tasks_per_s", 120u64);
    report.set("paper_swift_falkon_lan_tasks_per_s", 56u64);
    if let Some(hwm) = vm_hwm_bytes() {
        report.set("peak_rss_mb", hwm as f64 / 1e6);
    }
    let events = counters::global().snapshot();
    report.set("frames_encoded", events.get("frames_encoded"));
    report.set("frames_decoded", events.get("frames_decoded"));
    std::fs::write("BENCH_fig12.json", report.render())
        .expect("write BENCH_fig12.json");
    println!("\nwrote BENCH_fig12.json");
}
