//! §4 microbenchmarks: Falkon dispatch throughput, executor scalability,
//! and queue capacity.
//!
//! Paper: 487 tasks/s sustained dispatch (2500/s bundled), 54,000
//! executors managed, 1.5 million tasks queued.
//!
//! Real-clock measurements for throughput and in-process executor
//! scaling; the 54K-executor and 1.5M-queue points run on the
//! virtual-time model (54K OS threads is not a one-box experiment) with
//! memory accounting.

use std::sync::Arc;
use std::time::Instant;

use gridswift::falkon::{FalkonService, FalkonServiceConfig, RealDrpPolicy};
use gridswift::metrics::Table;
use gridswift::providers::AppTask;
use gridswift::sim::falkon_model::{DrpPolicy, FalkonConfig, FalkonSim};
use gridswift::util::mem::rss_bytes;

fn task(id: u64) -> AppTask {
    AppTask {
        id,
        key: format!("k{id}"),
        executable: "sleep0".into(),
        args: vec![],
        inputs: vec![],
        outputs: vec![],
    }
}

fn throughput(executors: usize, n: u64) -> f64 {
    let svc = FalkonService::start(
        FalkonServiceConfig {
            drp: RealDrpPolicy::static_pool(executors),
            executor_overhead: std::time::Duration::ZERO,
        },
        Arc::new(|_t: &AppTask| Ok(())),
    );
    let (tx, rx) = std::sync::mpsc::channel();
    let t0 = Instant::now();
    for i in 0..n {
        let tx = tx.clone();
        svc.submit(task(i), Box::new(move |r| {
            let _ = tx.send(r.ok);
        }));
    }
    for _ in 0..n {
        rx.recv().unwrap();
    }
    n as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    println!("== Falkon microbenchmarks (paper §4) ==\n");

    // 1. Sustained dispatch throughput (real clock).
    println!("-- dispatch throughput (sleep-0 tasks, real clock) --");
    let mut t = Table::new(&["Executors", "tasks/s (ours)", "paper"]);
    for execs in [1usize, 2, 4, 8, 16] {
        let rate = throughput(execs, 50_000);
        t.row(&[
            execs.to_string(),
            format!("{rate:.0}"),
            if execs == 4 { "487 (sustained)" } else { "-" }.to_string(),
        ]);
    }
    t.print();

    // 2. Real executor scaling on this box.
    println!("\n-- real executor registry scaling --");
    let before = rss_bytes().unwrap_or(0);
    let svc = FalkonService::start(
        FalkonServiceConfig {
            drp: RealDrpPolicy::static_pool(512),
            executor_overhead: std::time::Duration::ZERO,
        },
        Arc::new(|_t: &AppTask| Ok(())),
    );
    while svc.live_executors() < 512 {
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let after = rss_bytes().unwrap_or(0);
    println!(
        "  512 live executor threads; ~{:.1} KB RSS each",
        (after.saturating_sub(before)) as f64 / 512.0 / 1024.0
    );
    let rate = {
        let (tx, rx) = std::sync::mpsc::channel();
        let n = 50_000u64;
        let t0 = Instant::now();
        for i in 0..n {
            let tx = tx.clone();
            svc.submit(task(i), Box::new(move |r| {
                let _ = tx.send(r.ok);
            }));
        }
        for _ in 0..n {
            rx.recv().unwrap();
        }
        n as f64 / t0.elapsed().as_secs_f64()
    };
    println!("  dispatch rate with 512 executors: {rate:.0} tasks/s");
    drop(svc);

    // 3. Paper-scale registry + queue (virtual-time model + memory).
    println!("\n-- paper-scale capacity (model) --");
    let before = rss_bytes().unwrap_or(0);
    let mut sim = FalkonSim::new(FalkonConfig {
        dispatch_cost: 2053,
        executor_overhead: 45_000,
        drp: DrpPolicy::static_pool(54_000),
    });
    sim.register(54_000, 0);
    for i in 0..1_500_000usize {
        sim.submit(i);
    }
    let after = rss_bytes().unwrap_or(0);
    println!(
        "  54,000 executors registered + 1,500,000 tasks queued (paper: 54K / 1.5M)"
    );
    println!(
        "  state fits in {:.0} MB ({} peak queue, {} executors)",
        (after.saturating_sub(before)) as f64 / 1e6,
        sim.peak_queue,
        sim.live_executors(),
    );
    // Drain a slice in virtual time to show the dispatcher at scale.
    let mut now = 0u64;
    let mut dispatched = 0u64;
    while dispatched < 100_000 {
        if let Some((exec, _task, start)) = sim.try_dispatch(now) {
            now = start;
            sim.finish(exec, now, 0);
            dispatched += 1;
        } else {
            break;
        }
    }
    println!(
        "  model dispatch of 100K tasks at calibrated 2.053ms/task = {:.0} tasks/s sustained",
        dispatched as f64 / (now as f64 / 1e6)
    );
}
