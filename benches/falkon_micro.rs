//! §4 microbenchmarks: Falkon dispatch throughput, executor scalability,
//! and queue capacity.
//!
//! Paper: 487 tasks/s sustained dispatch (2500/s bundled), 54,000
//! executors managed, 1.5 million tasks queued.
//!
//! Real-clock measurements for throughput and in-process executor
//! scaling; the 54K-executor and 1.5M-queue points run on the
//! virtual-time model (54K OS threads is not a one-box experiment) with
//! memory accounting.
//!
//! Machine-readable output: writes `BENCH_dispatch.json` (tasks/s for
//! the single-submit and batched-submit paths, p50/p99 dispatch latency,
//! core count) so later PRs can track dispatch-core regressions.
//!
//! `--quick` shrinks task counts and skips the 512-executor and
//! paper-scale sections (CI smoke mode).

use std::sync::Arc;
use std::time::Instant;

use gridswift::falkon::service::TaskDone;
use gridswift::falkon::{FalkonService, FalkonServiceConfig, RealDrpPolicy};
use gridswift::metrics::Table;
use gridswift::providers::AppTask;
use gridswift::sim::falkon_model::{DrpPolicy, FalkonConfig, FalkonSim};
use gridswift::util::json::Json;
use gridswift::util::mem::rss_bytes;

// Same task shape as the seed benchmark (including the per-task key
// allocation on the submit side) so tasks/s stays comparable across
// revisions of the dispatch core.
fn task(id: u64) -> AppTask {
    AppTask {
        id,
        key: format!("k{id}"),
        executable: "sleep0".into(),
        args: vec![],
        inputs: vec![],
        outputs: vec![],
    }
}

/// One throughput run: returns (tasks/s, sorted dispatch waits in us).
struct RunStats {
    rate: f64,
    waits_us: Vec<u64>,
}

impl RunStats {
    fn percentile(&self, p: f64) -> u64 {
        if self.waits_us.is_empty() {
            return 0;
        }
        let idx = ((self.waits_us.len() - 1) as f64 * p).round() as usize;
        self.waits_us[idx]
    }
}

fn run_single(svc: &FalkonService, n: u64) -> RunStats {
    let (tx, rx) = std::sync::mpsc::channel();
    let t0 = Instant::now();
    for i in 0..n {
        let tx = tx.clone();
        svc.submit(task(i), Box::new(move |r| {
            let _ = tx.send(r.wait_us);
        }));
    }
    let mut waits_us: Vec<u64> = Vec::with_capacity(n as usize);
    for _ in 0..n {
        waits_us.push(rx.recv().unwrap());
    }
    let rate = n as f64 / t0.elapsed().as_secs_f64();
    waits_us.sort_unstable();
    RunStats { rate, waits_us }
}

fn run_batched(svc: &FalkonService, n: u64, chunk: u64) -> RunStats {
    let (tx, rx) = std::sync::mpsc::channel();
    let t0 = Instant::now();
    let mut i = 0u64;
    while i < n {
        let hi = (i + chunk).min(n);
        let batch: Vec<(AppTask, TaskDone)> = (i..hi)
            .map(|id| {
                let tx = tx.clone();
                let done: TaskDone = Box::new(move |r| {
                    let _ = tx.send(r.wait_us);
                });
                (task(id), done)
            })
            .collect();
        svc.submit_batch(batch);
        i = hi;
    }
    let mut waits_us: Vec<u64> = Vec::with_capacity(n as usize);
    for _ in 0..n {
        waits_us.push(rx.recv().unwrap());
    }
    let rate = n as f64 / t0.elapsed().as_secs_f64();
    waits_us.sort_unstable();
    RunStats { rate, waits_us }
}

fn service(executors: usize) -> Arc<FalkonService> {
    FalkonService::start(
        FalkonServiceConfig {
            drp: RealDrpPolicy::static_pool(executors),
            executor_overhead: std::time::Duration::ZERO,
        },
        Arc::new(|_t: &AppTask| Ok(())),
    )
}

fn throughput(executors: usize, n: u64) -> RunStats {
    let svc = service(executors);
    run_single(&svc, n)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n: u64 = if quick { 10_000 } else { 50_000 };
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    println!("== Falkon microbenchmarks (paper §4) ==");
    println!("   {cores} cores, {n} tasks per point{}\n", if quick { " (quick)" } else { "" });

    // 1. Sustained dispatch throughput (real clock).
    println!("-- dispatch throughput (sleep-0 tasks, real clock) --");
    let mut report = Json::obj();
    report.set("bench", "falkon_micro");
    report.set("cores", cores);
    report.set("quick", quick);
    report.set("n_tasks", n);
    report.set("paper_tasks_per_s", 487u64);
    let mut per_exec = Vec::new();
    let mut t = Table::new(&["Executors", "tasks/s (ours)", "p50 us", "p99 us", "paper"]);
    let mut headline: Option<RunStats> = None;
    for execs in [1usize, 2, 4, 8, 16] {
        let stats = throughput(execs, n);
        t.row(&[
            execs.to_string(),
            format!("{:.0}", stats.rate),
            stats.percentile(0.50).to_string(),
            stats.percentile(0.99).to_string(),
            if execs == 4 { "487 (sustained)" } else { "-" }.to_string(),
        ]);
        let mut point = Json::obj();
        point.set("executors", execs);
        point.set("tasks_per_s", stats.rate);
        point.set("p50_dispatch_us", stats.percentile(0.50));
        point.set("p99_dispatch_us", stats.percentile(0.99));
        per_exec.push(point);
        if execs == 4 {
            headline = Some(stats);
        }
    }
    t.print();
    let headline = headline.expect("4-executor point");
    let mut single = Json::obj();
    single.set("executors", 4u64);
    single.set("tasks_per_s", headline.rate);
    single.set("p50_dispatch_us", headline.percentile(0.50));
    single.set("p99_dispatch_us", headline.percentile(0.99));
    report.set("single_submit", single);
    report.set("per_executor", Json::Arr(per_exec));

    // 2. Batched submit/complete path (one lock + wakeup per bundle).
    println!("\n-- batched submit path (chunks of 1024) --");
    let svc = service(4);
    let batched = run_batched(&svc, n, 1024);
    println!(
        "  {:.0} tasks/s, p50 {} us, p99 {} us ({:.1}x the single-submit path)",
        batched.rate,
        batched.percentile(0.50),
        batched.percentile(0.99),
        batched.rate / headline.rate,
    );
    let mut b = Json::obj();
    b.set("executors", 4u64);
    b.set("chunk", 1024u64);
    b.set("tasks_per_s", batched.rate);
    b.set("p50_dispatch_us", batched.percentile(0.50));
    b.set("p99_dispatch_us", batched.percentile(0.99));
    report.set("batched_submit", b);
    drop(svc);

    if !quick {
        // 3. Real executor scaling on this box.
        println!("\n-- real executor registry scaling --");
        let before = rss_bytes().unwrap_or(0);
        let svc = service(512);
        while svc.live_executors() < 512 {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let after = rss_bytes().unwrap_or(0);
        println!(
            "  512 live executor threads; ~{:.1} KB RSS each",
            (after.saturating_sub(before)) as f64 / 512.0 / 1024.0
        );
        let stats = run_single(&svc, n);
        println!("  dispatch rate with 512 executors: {:.0} tasks/s", stats.rate);
        report.set("executors_512_tasks_per_s", stats.rate);
        drop(svc);

        // 4. Paper-scale registry + queue (virtual-time model + memory).
        println!("\n-- paper-scale capacity (model) --");
        let before = rss_bytes().unwrap_or(0);
        let mut sim = FalkonSim::new(FalkonConfig {
            dispatch_cost: 2053,
            executor_overhead: 45_000,
            drp: DrpPolicy::static_pool(54_000),
            ..Default::default()
        });
        sim.register(54_000, 0);
        for i in 0..1_500_000usize {
            sim.submit(i);
        }
        let after = rss_bytes().unwrap_or(0);
        println!(
            "  54,000 executors registered + 1,500,000 tasks queued (paper: 54K / 1.5M)"
        );
        println!(
            "  state fits in {:.0} MB ({} peak queue, {} executors)",
            (after.saturating_sub(before)) as f64 / 1e6,
            sim.peak_queue,
            sim.live_executors(),
        );
        // Drain a slice in virtual time to show the dispatcher at scale.
        let mut now = 0u64;
        let mut dispatched = 0u64;
        while dispatched < 100_000 {
            if let Some((exec, _task, start)) = sim.try_dispatch(now) {
                now = start;
                sim.finish(exec, now, 0);
                dispatched += 1;
            } else {
                break;
            }
        }
        println!(
            "  model dispatch of 100K tasks at calibrated 2.053ms/task = {:.0} tasks/s sustained",
            dispatched as f64 / (now as f64 / 1e6)
        );
    }

    let out = report.render();
    std::fs::write("BENCH_dispatch.json", &out).expect("write BENCH_dispatch.json");
    println!("\nwrote BENCH_dispatch.json");
    let floor = 10_000.0;
    if headline.rate < floor {
        println!(
            "WARNING: single-submit rate {:.0} tasks/s below the {floor:.0}/s target",
            headline.rate
        );
    }
}
