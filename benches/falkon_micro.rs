//! §4 microbenchmarks: Falkon dispatch throughput, executor scalability,
//! and queue capacity.
//!
//! Paper: 487 tasks/s sustained dispatch (2500/s bundled), 54,000
//! executors managed, 1.5 million tasks queued.
//!
//! Real-clock measurements for throughput and in-process executor
//! scaling; the 54K-executor and 1.5M-queue points run on the
//! virtual-time model (54K OS threads is not a one-box experiment) with
//! memory accounting.
//!
//! Machine-readable output: writes `BENCH_dispatch.json` (tasks/s for
//! the single-submit and batched-submit paths, p50/p99 dispatch latency,
//! core count) so later PRs can track dispatch-core regressions.
//!
//! `--quick` shrinks task counts and skips the 512-executor and
//! paper-scale sections (CI smoke mode).

use std::io::Cursor;
use std::sync::Arc;
use std::time::Instant;

use gridswift::falkon::protocol::{
    decode_submitb_body, encode_submitb, encode_submitb_bin, SubmitbBinIter,
};
use gridswift::falkon::service::TaskDone;
use gridswift::falkon::{
    FalkonClient, FalkonService, FalkonServiceConfig, FalkonTcpServer,
    MutexShardedQueue, RealDrpPolicy, ShardedQueue, TaskSpec,
};
use gridswift::metrics::stats::percentile_sorted;
use gridswift::metrics::Table;
use gridswift::providers::AppTask;
use gridswift::sim::falkon_model::{DrpPolicy, FalkonConfig, FalkonSim};
use gridswift::telemetry::counters;
use gridswift::util::json::Json;
use gridswift::util::mem::{rss_bytes, vm_hwm_bytes};
use gridswift::util::DetRng;

// Same task shape as the seed benchmark (including the per-task key
// allocation on the submit side) so tasks/s stays comparable across
// revisions of the dispatch core.
fn task(id: u64) -> AppTask {
    AppTask {
        id,
        key: format!("k{id}"),
        executable: "sleep0".into(),
        args: vec![],
        inputs: vec![],
        outputs: vec![],
    }
}

/// One throughput run: returns (tasks/s, sorted dispatch waits in us).
struct RunStats {
    rate: f64,
    waits_us: Vec<f64>,
}

impl RunStats {
    /// Nearest-rank percentile, p in [0, 100] — the same
    /// `metrics::stats` helper `Timeline::p50/p95/p99` sit on, so
    /// bench and sim percentiles can never drift apart.
    fn percentile(&self, p: f64) -> u64 {
        percentile_sorted(&self.waits_us, p) as u64
    }
}

/// Sort a drained wait-time sample into the f64 shape
/// [`percentile_sorted`] consumes (outside any timed region).
fn sorted_sample(mut waits: Vec<u64>) -> Vec<f64> {
    waits.sort_unstable();
    waits.into_iter().map(|w| w as f64).collect()
}

fn run_single(svc: &FalkonService, n: u64) -> RunStats {
    let (tx, rx) = std::sync::mpsc::channel();
    let t0 = Instant::now();
    for i in 0..n {
        let tx = tx.clone();
        svc.submit(task(i), Box::new(move |r| {
            let _ = tx.send(r.wait_us);
        }));
    }
    let mut waits: Vec<u64> = Vec::with_capacity(n as usize);
    for _ in 0..n {
        waits.push(rx.recv().unwrap());
    }
    let rate = n as f64 / t0.elapsed().as_secs_f64();
    RunStats { rate, waits_us: sorted_sample(waits) }
}

fn run_batched(svc: &FalkonService, n: u64, chunk: u64) -> RunStats {
    let (tx, rx) = std::sync::mpsc::channel();
    let t0 = Instant::now();
    let mut i = 0u64;
    while i < n {
        let hi = (i + chunk).min(n);
        let batch: Vec<(AppTask, TaskDone)> = (i..hi)
            .map(|id| {
                let tx = tx.clone();
                let done: TaskDone = Box::new(move |r| {
                    let _ = tx.send(r.wait_us);
                });
                (task(id), done)
            })
            .collect();
        svc.submit_batch(batch);
        i = hi;
    }
    let mut waits: Vec<u64> = Vec::with_capacity(n as usize);
    for _ in 0..n {
        waits.push(rx.recv().unwrap());
    }
    let rate = n as f64 / t0.elapsed().as_secs_f64();
    RunStats { rate, waits_us: sorted_sample(waits) }
}

/// Seeded wire workload: realistic Montage-style stage names with a
/// few short args per task (the shape fig12 pushes over the wire).
fn codec_workload(n: usize) -> Vec<TaskSpec> {
    let stages = ["mProjectPP", "mDiffFit", "mBackground", "sleep0"];
    let mut rng = DetRng::new(0xC0DEC);
    (0..n)
        .map(|i| TaskSpec {
            id: i as u64,
            executable: stages[rng.below(4) as usize].to_string(),
            args: (0..rng.below(4))
                .map(|k| format!("arg{}-{}", k, rng.below(1000)))
                .collect(),
        })
        .collect()
}

/// Text-framing codec throughput: encode a `SUBMITB` frame, decode it
/// the way the server does (tokenize + parse into owned specs).
fn codec_text_rate(tasks: &[TaskSpec], rounds: usize) -> f64 {
    let mut sink = 0u64;
    let t0 = Instant::now();
    for _ in 0..rounds {
        let wire = encode_submitb(tasks).unwrap();
        let body = wire.splitn(2, '\n').nth(1).unwrap();
        let decoded = decode_submitb_body(tasks.len(), &mut Cursor::new(body)).unwrap();
        sink = sink.wrapping_add(decoded.len() as u64).wrapping_add(decoded[0].id);
    }
    let secs = t0.elapsed().as_secs_f64();
    std::hint::black_box(sink);
    (tasks.len() * rounds) as f64 / secs
}

/// Binary-framing codec throughput: encode into a reused buffer, decode
/// the way the binary server loop does (borrowing iterator + one reused
/// arg spine — the zero-alloc path).
fn codec_bin_rate(tasks: &[TaskSpec], rounds: usize) -> f64 {
    let mut buf = Vec::new();
    let mut args: Vec<String> = Vec::new();
    let mut sink = 0u64;
    let t0 = Instant::now();
    for _ in 0..rounds {
        encode_submitb_bin(tasks, &mut buf).unwrap();
        // Skip the [u32 len][u8 opcode] header the socket reader strips.
        let mut iter = SubmitbBinIter::parse(&buf[5..]).unwrap();
        while let Some((id, exe)) = iter.next_task(&mut args).unwrap() {
            sink = sink
                .wrapping_add(id)
                .wrapping_add(exe.len() as u64)
                .wrapping_add(args.len() as u64);
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    std::hint::black_box(sink);
    (tasks.len() * rounds) as f64 / secs
}

/// End-to-end TCP throughput through the real endpoint in the given
/// framing: batched submits, all acks drained.
fn tcp_rate(binary: bool, n: u64) -> f64 {
    let svc = service(4);
    let server = FalkonTcpServer::start(Arc::clone(&svc), "127.0.0.1:0").unwrap();
    let mut client = if binary {
        FalkonClient::connect_binary(server.addr()).unwrap()
    } else {
        FalkonClient::connect(server.addr()).unwrap()
    };
    let specs: Vec<TaskSpec> = (0..n)
        .map(|i| TaskSpec { id: i, executable: "sleep0".into(), args: vec![] })
        .collect();
    let t0 = Instant::now();
    for chunk in specs.chunks(1024) {
        client.submit_batch(chunk).unwrap();
    }
    for _ in 0..n {
        client.next_result().unwrap();
    }
    n as f64 / t0.elapsed().as_secs_f64()
}

/// One queue-contention run for either queue flavor: `workers` threads
/// hammer a single shard with interleaved 32-task batch pushes and
/// batch pops until each has moved `per_worker` items. Returns items
/// moved per second across all workers. Implemented as a macro because
/// the two queues are distinct types with identical inherent APIs.
macro_rules! contention_rate {
    ($Q:ty, $workers:expr, $per_worker:expr) => {{
        let q: Arc<$Q> = Arc::new(<$Q>::new(1));
        let barrier = Arc::new(std::sync::Barrier::new($workers + 1));
        let mut handles = Vec::new();
        for w in 0..$workers {
            let q = Arc::clone(&q);
            let barrier = Arc::clone(&barrier);
            handles.push(std::thread::spawn(move || {
                let mut out: Vec<u64> = Vec::with_capacity(32);
                barrier.wait();
                let mut moved = 0usize;
                let mut i = 0u64;
                while moved < $per_worker {
                    let batch: Vec<u64> = (i..i + 32).collect();
                    i += 32;
                    q.push_batch(batch);
                    moved += q.try_pop_batch(w, 32, &mut out);
                    out.clear();
                }
            }));
        }
        barrier.wait();
        let t0 = Instant::now();
        for h in handles {
            h.join().unwrap();
        }
        ($workers * $per_worker) as f64 / t0.elapsed().as_secs_f64()
    }};
}

/// Best-of-3 wrapper (thermal/scheduler noise hurts, never helps).
fn best_of_3(mut f: impl FnMut() -> f64) -> f64 {
    (0..3).map(|_| f()).fold(0.0f64, f64::max)
}

fn service(executors: usize) -> Arc<FalkonService> {
    FalkonService::start(
        FalkonServiceConfig {
            drp: RealDrpPolicy::static_pool(executors),
            executor_overhead: std::time::Duration::ZERO,
        },
        Arc::new(|_t: &AppTask| Ok(())),
    )
}

fn throughput(executors: usize, n: u64) -> RunStats {
    let svc = service(executors);
    run_single(&svc, n)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n: u64 = if quick { 10_000 } else { 50_000 };
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    println!("== Falkon microbenchmarks (paper §4) ==");
    println!("   {cores} cores, {n} tasks per point{}\n", if quick { " (quick)" } else { "" });

    // 1. Sustained dispatch throughput (real clock).
    println!("-- dispatch throughput (sleep-0 tasks, real clock) --");
    let mut report = Json::obj();
    report.set("bench", "falkon_micro");
    report.set("cores", cores);
    report.set("quick", quick);
    report.set("n_tasks", n);
    report.set("paper_tasks_per_s", 487u64);
    let mut per_exec = Vec::new();
    let mut t = Table::new(&["Executors", "tasks/s (ours)", "p50 us", "p99 us", "paper"]);
    let mut headline: Option<RunStats> = None;
    for execs in [1usize, 2, 4, 8, 16] {
        let stats = throughput(execs, n);
        t.row(&[
            execs.to_string(),
            format!("{:.0}", stats.rate),
            stats.percentile(50.0).to_string(),
            stats.percentile(99.0).to_string(),
            if execs == 4 { "487 (sustained)" } else { "-" }.to_string(),
        ]);
        let mut point = Json::obj();
        point.set("executors", execs);
        point.set("tasks_per_s", stats.rate);
        point.set("p50_dispatch_us", stats.percentile(50.0));
        point.set("p99_dispatch_us", stats.percentile(99.0));
        per_exec.push(point);
        if execs == 4 {
            headline = Some(stats);
        }
    }
    t.print();
    let headline = headline.expect("4-executor point");
    let mut single = Json::obj();
    single.set("executors", 4u64);
    single.set("tasks_per_s", headline.rate);
    single.set("p50_dispatch_us", headline.percentile(50.0));
    single.set("p99_dispatch_us", headline.percentile(99.0));
    report.set("single_submit", single);
    report.set("per_executor", Json::Arr(per_exec));

    // 2. Batched submit/complete path (one lock + wakeup per bundle).
    println!("\n-- batched submit path (chunks of 1024) --");
    let svc = service(4);
    let batched = run_batched(&svc, n, 1024);
    println!(
        "  {:.0} tasks/s, p50 {} us, p99 {} us ({:.1}x the single-submit path)",
        batched.rate,
        batched.percentile(50.0),
        batched.percentile(99.0),
        batched.rate / headline.rate,
    );
    let mut b = Json::obj();
    b.set("executors", 4u64);
    b.set("chunk", 1024u64);
    b.set("tasks_per_s", batched.rate);
    b.set("p50_dispatch_us", batched.percentile(50.0));
    b.set("p99_dispatch_us", batched.percentile(99.0));
    report.set("batched_submit", b);
    drop(svc);

    // 2b. Wire codec: text vs binary framing (pure CPU, no sockets).
    println!("\n-- wire codec: text vs binary SUBMITB framing --");
    let workload = codec_workload(1024);
    let rounds = if quick { 200 } else { 1000 };
    let text_codec = best_of_3(|| codec_text_rate(&workload, rounds));
    let bin_codec = best_of_3(|| codec_bin_rate(&workload, rounds));
    println!(
        "  text  {:.0} tasks/s\n  binary {:.0} tasks/s ({:.1}x)",
        text_codec,
        bin_codec,
        bin_codec / text_codec,
    );
    report.set("real_text_codec_tasks_per_s", text_codec);
    report.set("real_binary_codec_tasks_per_s", bin_codec);
    // Acceptance: fixed-width reads + borrowed decode must beat integer
    // formatting + tokenization + per-task owned specs.
    assert!(
        bin_codec > text_codec,
        "binary codec ({bin_codec:.0}/s) must beat text ({text_codec:.0}/s)"
    );

    // 2c. End-to-end TCP dispatch in both framings.
    println!("\n-- end-to-end TCP dispatch: text vs binary framing --");
    let text_tcp = tcp_rate(false, n);
    let bin_tcp = tcp_rate(true, n);
    println!(
        "  text  {:.0} tasks/s\n  binary {:.0} tasks/s ({:.2}x)",
        text_tcp,
        bin_tcp,
        bin_tcp / text_tcp,
    );
    report.set("real_text_tcp_tasks_per_s", text_tcp);
    report.set("real_binary_tcp_tasks_per_s", bin_tcp);

    // 2d. Shard queue contention: lock-free ring vs the Mutex baseline
    // on one shard, at 1 worker (uncontended floor) and 8 workers.
    println!("\n-- shard queue contention: lock-free ring vs Mutex deque --");
    let per_worker = if quick { 50_000 } else { 200_000 };
    let mut contention = Table::new(&["Workers", "mutex ops/s", "lock-free ops/s", "ratio"]);
    let mut rates = Vec::new();
    for workers in [1usize, 8] {
        let mutex = best_of_3(|| contention_rate!(MutexShardedQueue<u64>, workers, per_worker));
        let lockfree = best_of_3(|| contention_rate!(ShardedQueue<u64>, workers, per_worker));
        contention.row(&[
            workers.to_string(),
            format!("{mutex:.0}"),
            format!("{lockfree:.0}"),
            format!("{:.2}x", lockfree / mutex),
        ]);
        report.set(&format!("queue_contention_mutex_{workers}w_ops_per_s"), mutex);
        report.set(&format!("queue_contention_lockfree_{workers}w_ops_per_s"), lockfree);
        rates.push((workers, mutex, lockfree));
    }
    contention.print();
    // Acceptance: no slower uncontended (10% tolerance for run noise),
    // faster under contention.
    for (workers, mutex, lockfree) in rates {
        match workers {
            1 => assert!(
                lockfree * 1.1 >= mutex,
                "lock-free queue ({lockfree:.0}/s) must not trail the Mutex \
                 baseline ({mutex:.0}/s) at 1 worker"
            ),
            _ => assert!(
                lockfree > mutex,
                "lock-free queue ({lockfree:.0}/s) must beat the Mutex \
                 baseline ({mutex:.0}/s) at {workers} workers"
            ),
        }
    }

    if !quick {
        // 3. Real executor scaling on this box.
        println!("\n-- real executor registry scaling --");
        let before = rss_bytes().unwrap_or(0);
        let svc = service(512);
        while svc.live_executors() < 512 {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let after = rss_bytes().unwrap_or(0);
        println!(
            "  512 live executor threads; ~{:.1} KB RSS each",
            (after.saturating_sub(before)) as f64 / 512.0 / 1024.0
        );
        let stats = run_single(&svc, n);
        println!("  dispatch rate with 512 executors: {:.0} tasks/s", stats.rate);
        report.set("executors_512_tasks_per_s", stats.rate);
        drop(svc);

        // 4. Paper-scale registry + queue (virtual-time model + memory).
        println!("\n-- paper-scale capacity (model) --");
        let before = rss_bytes().unwrap_or(0);
        let mut sim = FalkonSim::new(FalkonConfig {
            dispatch_cost: 2053,
            executor_overhead: 45_000,
            drp: DrpPolicy::static_pool(54_000),
            ..Default::default()
        });
        sim.register(54_000, 0);
        for i in 0..1_500_000usize {
            sim.submit(i);
        }
        let after = rss_bytes().unwrap_or(0);
        println!(
            "  54,000 executors registered + 1,500,000 tasks queued (paper: 54K / 1.5M)"
        );
        println!(
            "  state fits in {:.0} MB ({} peak queue, {} executors)",
            (after.saturating_sub(before)) as f64 / 1e6,
            sim.peak_queue,
            sim.live_executors(),
        );
        // Drain a slice in virtual time to show the dispatcher at scale.
        let mut now = 0u64;
        let mut dispatched = 0u64;
        while dispatched < 100_000 {
            if let Some((exec, _task, start)) = sim.try_dispatch(now) {
                now = start;
                sim.finish(exec, now, 0);
                dispatched += 1;
            } else {
                break;
            }
        }
        println!(
            "  model dispatch of 100K tasks at calibrated 2.053ms/task = {:.0} tasks/s sustained",
            dispatched as f64 / (now as f64 / 1e6)
        );
    }

    // Peak RSS + global telemetry totals ride along in every bench
    // report so trend tracking sees memory and wire-event regressions.
    if let Some(hwm) = vm_hwm_bytes() {
        report.set("peak_rss_mb", hwm as f64 / 1e6);
    }
    let events = counters::global().snapshot();
    report.set("frames_encoded", events.get("frames_encoded"));
    report.set("frames_decoded", events.get("frames_decoded"));
    report.set("tasks_dispatched", events.get("tasks_dispatched"));

    let out = report.render();
    std::fs::write("BENCH_dispatch.json", &out).expect("write BENCH_dispatch.json");
    println!("\nwrote BENCH_dispatch.json");
    let floor = 10_000.0;
    if headline.rate < floor {
        println!(
            "WARNING: single-submit rate {:.0} tasks/s below the {floor:.0}/s target",
            headline.rate
        );
    }
}
