//! Figure 6: efficiency of resource usage for varying task lengths on 64
//! processors — Falkon vs PBS vs Condor 6.7.2 vs Condor 6.9.3 (derived).
//!
//! Discrete-event simulation with models calibrated to the paper's
//! measured throughputs (DESIGN.md §2). The paper's shape: Falkon ~95%
//! at 1 s tasks and ~99% at 8 s; the LRMs are <1% at 1 s and need
//! ~1200 s tasks for 90%.

use gridswift::metrics::plot::line_chart;
use gridswift::metrics::Table;
use gridswift::sim::driver::fig6_point;

fn main() {
    println!("== Figure 6: resource-usage efficiency, 64 procs, 64 tasks ==\n");
    let lengths = [
        1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1200.0,
        3600.0, 16384.0,
    ];
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    let mut t = Table::new(&[
        "Task len (s)",
        "Falkon",
        "PBS",
        "Condor-6.7.2",
        "Condor-6.9.3",
    ]);
    for &len in &lengths {
        let eff = fig6_point(len, 64, 42);
        let mut row = vec![format!("{len}")];
        for (name, e) in &eff {
            row.push(format!("{:.1}%", e * 100.0));
            match series.iter_mut().find(|(n, _)| n == name) {
                Some((_, pts)) => pts.push((len, *e)),
                None => series.push((name.clone(), vec![(len, *e)])),
            }
        }
        t.row(&row);
    }
    t.print();
    let chart_series: Vec<(&str, Vec<(f64, f64)>)> = series
        .iter()
        .map(|(n, pts)| (n.as_str(), pts.clone()))
        .collect();
    println!();
    print!(
        "{}",
        line_chart("efficiency vs task length (log x)", &chart_series, 60, 14, true)
    );

    // Paper checkpoints.
    let get = |len: f64, name: &str| -> f64 {
        fig6_point(len, 64, 42)
            .into_iter()
            .find(|(n, _)| n == name)
            .unwrap()
            .1
    };
    println!("\npaper checkpoints:");
    println!(
        "  Falkon @1s  = {:.1}%   (paper: 95%)",
        get(1.0, "Falkon") * 100.0
    );
    println!(
        "  Falkon @8s  = {:.1}%   (paper: 99%)",
        get(8.0, "Falkon") * 100.0
    );
    println!(
        "  PBS    @1s  = {:.1}%    (paper: <1%)",
        get(1.0, "PBS") * 100.0
    );
    println!(
        "  Condor @1200s = {:.1}%  (paper: ~90%)",
        get(1200.0, "Condor-6.7.2") * 100.0
    );
    println!(
        "  Condor-6.9.3 @50s = {:.1}%  (paper derived: ~90%)",
        get(50.0, "Condor-6.9.3") * 100.0
    );
}
