//! Data diffusion (paper §3.13): cache-hit vs shared-FS-every-time
//! throughput on a locality-heavy fMRI-style DAG, in virtual time.
//!
//! The workload is `Dag::fmri_datasets`: per-volume four-stage
//! pipelines where stage k reads exactly the dataset stage k-1 wrote.
//! Rows:
//!
//! - **shared-FS every time** — no cache: every task stages its full
//!   input from (and writes its output back to) the GPFS fluid-flow
//!   model, the paper's Figure 8 bottleneck.
//! - **cache hit** — data diffusion with ample per-executor capacity:
//!   the locality-aware dispatcher lands stages on the executor
//!   already holding their input, staging only cold misses.
//! - **eviction pressure** — capacity of two volumes per executor:
//!   the LRU churns, measuring how much of the win survives.
//! - **executor faults** — cache-hit configuration plus three injected
//!   executor kills (`SimFaults::kill_executors`): dead executors drop
//!   their cache entries, in-flight tasks requeue, DRP re-provisions.
//!
//! A second section measures the peer-to-peer transfer network on a
//! locality-heavy fan-out (one hot dataset read by 64 consumers across
//! 16 executors), comparing the three ways a consumer can get its
//! input (`sim_peer_*` rows):
//!
//! - **local hit** — the dataset is already cached on every executor
//!   (pre-warmed): staging-free upper bound.
//! - **peer fetch** — one executor holds it; misses fetch over
//!   dedicated 1 Gb/s peer links, each pair its own fluid channel.
//! - **shared-FS cold** — no peer links (the zero-link topology):
//!   misses restage through the contended GPFS fluid.
//!
//! All rows are deterministic virtual-time sims, so CI gates their
//! `sim_*` keys (>20% regression fails) via `scripts/bench_trend.py`.

use gridswift::diffusion::{
    CacheStats, DatasetRef, DiffusionConfig, LinkSpec, LinkTopology,
};
use gridswift::metrics::Table;
use gridswift::sim::driver::{Driver, Mode, SimFaults};
use gridswift::sim::falkon_model::{DrpPolicy, FalkonConfig};
use gridswift::sim::{Dag, SharedFs, SimTask};
use gridswift::util::json::Json;
use gridswift::util::time::secs;
use gridswift::util::DetRng;
use gridswift::telemetry::counters;
use gridswift::util::mem::vm_hwm_bytes;

const MB: u64 = 1024 * 1024;
/// Per-volume intermediate size (the paper's fMRI volumes are a few
/// MB; 64 MB makes staging the dominant cost, the Figure 8 regime).
const VOLUME_MB: u64 = 64;
const EXECUTORS: usize = 16;
const SEED: u64 = 0xD1FF;

fn falkon_mode() -> Mode {
    let mut cfg = FalkonConfig::default();
    cfg.drp = DrpPolicy::static_pool(EXECUTORS);
    cfg.drp.allocation_latency = 0;
    Mode::Falkon { cfg }
}

struct Row {
    name: &'static str,
    tasks_per_s: f64,
    makespan_secs: f64,
    fs_gb: f64,
    stats: CacheStats,
}

fn run(
    name: &'static str,
    volumes: usize,
    capacity_mb: u64,
    kills: Vec<(u64, usize)>,
) -> Row {
    let mut rng = DetRng::new(SEED);
    let dag = Dag::fmri_datasets(
        volumes,
        [2.0, 2.0, 3.0, 3.0],
        VOLUME_MB * MB,
        &mut rng,
    );
    let n = dag.len();
    let mut d = Driver::new(dag, falkon_mode(), SEED)
        .with_shared_fs(SharedFs::gpfs_8());
    if capacity_mb > 0 {
        d = d.with_diffusion(DiffusionConfig {
            capacity_bytes: capacity_mb * MB,
            ..Default::default()
        });
    }
    if !kills.is_empty() {
        d = d.with_faults(SimFaults {
            kill_executors: kills,
            ..Default::default()
        });
    }
    let o = d.run();
    assert_eq!(o.timeline.len(), n, "{name}: every task completes");
    Row {
        name,
        tasks_per_s: n as f64 / o.makespan_secs,
        makespan_secs: o.makespan_secs,
        fs_gb: o.fs_bytes / (1024.0 * 1024.0 * 1024.0),
        stats: o.cache_stats,
    }
}

/// Per-consumer input size for the peer-transfer rows: big enough that
/// staging dominates the 1 s of compute.
const PEER_DS_MB: u64 = 256;
const PEER_CONSUMERS: usize = 64;

/// The peer-network fan-out: `warm` producers each publish the hot
/// dataset on their executor (warm > 1 pre-seeds every executor for
/// the local-hit row; warm == 1 leaves a single holder), then 64
/// consumers read it.
fn peer_dag(warm: usize) -> Dag {
    let ds = DatasetRef { id: 1, bytes: PEER_DS_MB * MB };
    let mut dag = Dag::new();
    let producers: Vec<usize> = (0..warm)
        .map(|_| {
            dag.push(SimTask::new("produce", 1.0).with_datasets(vec![], vec![ds]))
        })
        .collect();
    for _ in 0..PEER_CONSUMERS {
        dag.push(
            SimTask::new("consume", 1.0)
                .with_deps(producers.clone())
                .with_datasets(vec![ds], vec![]),
        );
    }
    dag
}

struct PeerRow {
    name: &'static str,
    consumers_per_s: f64,
    makespan_secs: f64,
    fs_gb: f64,
    peer_gb: f64,
    stats: CacheStats,
}

/// One peer-network row: `warm` holders, the given link topology.
fn run_peer(name: &'static str, warm: usize, links: LinkTopology) -> PeerRow {
    let o = Driver::new(peer_dag(warm), falkon_mode(), SEED)
        .with_shared_fs(SharedFs::gpfs_8())
        .with_diffusion(DiffusionConfig {
            capacity_bytes: 16 << 30,
            links: Some(links),
            ..Default::default()
        })
        .run();
    assert_eq!(
        o.timeline.len(),
        warm + PEER_CONSUMERS,
        "{name}: every task completes"
    );
    PeerRow {
        name,
        consumers_per_s: PEER_CONSUMERS as f64 / o.makespan_secs,
        makespan_secs: o.makespan_secs,
        fs_gb: o.fs_bytes / (1024.0 * 1024.0 * 1024.0),
        peer_gb: o.peer_bytes / (1024.0 * 1024.0 * 1024.0),
        stats: o.cache_stats,
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let volumes = if quick { 16 } else { 64 };
    println!("== Data diffusion: fMRI-style pipeline, {volumes} volumes x 4 stages ==\n");

    let sharedfs = run("shared-FS every time", volumes, 0, vec![]);
    let cached = run("cache hit (2 GB/exec)", volumes, 2048, vec![]);
    let evict = run("eviction pressure (128 MB/exec)", volumes, 128, vec![]);
    let faults = run(
        "cache hit + 3 executor kills",
        volumes,
        2048,
        vec![(secs(10.0), 0), (secs(20.0), 1), (secs(30.0), 2)],
    );

    let mut t = Table::new(&[
        "Row",
        "tasks/s",
        "makespan (s)",
        "FS GB",
        "hits",
        "misses",
        "evictions",
    ]);
    for r in [&sharedfs, &cached, &evict, &faults] {
        t.row(&[
            r.name.into(),
            format!("{:.1}", r.tasks_per_s),
            format!("{:.1}", r.makespan_secs),
            format!("{:.2}", r.fs_gb),
            r.stats.hits.to_string(),
            r.stats.misses.to_string(),
            r.stats.evictions.to_string(),
        ]);
    }
    t.print();

    println!("\nshape checks:");
    println!(
        "  cache hit vs shared-FS: {:.2}x (locality skips staging)",
        cached.tasks_per_s / sharedfs.tasks_per_s
    );
    println!(
        "  eviction pressure keeps {:.0}% of the cache-hit win",
        100.0 * (evict.tasks_per_s - sharedfs.tasks_per_s)
            / (cached.tasks_per_s - sharedfs.tasks_per_s).max(1e-9)
    );
    println!(
        "  3 executor kills cost {:.1}% throughput vs fault-free cached",
        100.0 * (1.0 - faults.tasks_per_s / cached.tasks_per_s)
    );

    // The acceptance bar: data diffusion must beat restaging through
    // the shared FS on this locality-heavy DAG, and the pressure row
    // must actually evict.
    assert!(
        cached.tasks_per_s > sharedfs.tasks_per_s,
        "cache-hit row must beat shared-FS-every-time: {:.1} vs {:.1}",
        cached.tasks_per_s,
        sharedfs.tasks_per_s
    );
    assert!(cached.stats.hits > 0, "cache-hit row must actually hit");
    assert!(
        evict.stats.evictions > 0,
        "eviction-pressure row must actually evict"
    );

    // ------------------------------------------------------------------
    // Peer-to-peer transfer network (the PR-5 rows)
    // ------------------------------------------------------------------
    println!(
        "\n== Peer transfer network: 1 hot {PEER_DS_MB} MB dataset, \
         {PEER_CONSUMERS} consumers x {EXECUTORS} executors ==\n"
    );
    // Uplink estimate derived from the very fluid the misses stage
    // through (per-stream NIC cap + op latency), so plan and fluid
    // agree; peers get dedicated 1 Gb/s pair links.
    let fs_uplink = SharedFs::gpfs_8().link_spec();
    let peer_link = LinkSpec::gbit(1_000);
    let local = run_peer(
        "local hit (pre-warmed everywhere)",
        EXECUTORS,
        LinkTopology::uniform(EXECUTORS, fs_uplink, peer_link),
    );
    let peer = run_peer(
        "peer fetch (1 holder, 1 Gb/s mesh)",
        1,
        LinkTopology::uniform(EXECUTORS, fs_uplink, peer_link),
    );
    let cold = run_peer(
        "shared-FS cold (1 holder, no links)",
        1,
        LinkTopology::shared_only(EXECUTORS, fs_uplink),
    );
    let mut pt = Table::new(&[
        "Row",
        "consumers/s",
        "makespan (s)",
        "FS GB",
        "peer GB",
        "hits",
        "misses",
    ]);
    for r in [&local, &peer, &cold] {
        pt.row(&[
            r.name.into(),
            format!("{:.1}", r.consumers_per_s),
            format!("{:.1}", r.makespan_secs),
            format!("{:.2}", r.fs_gb),
            format!("{:.2}", r.peer_gb),
            r.stats.hits.to_string(),
            r.stats.misses.to_string(),
        ]);
    }
    pt.print();
    println!(
        "\n  peer fetch recovers {:.0}% of the local-hit win over cold restage",
        100.0 * (peer.consumers_per_s - cold.consumers_per_s)
            / (local.consumers_per_s - cold.consumers_per_s).max(1e-9)
    );

    // Acceptance: routing misses to a peer holder must beat restaging
    // them cold through the shared FS, and the rows must exercise what
    // they claim to.
    assert!(
        peer.consumers_per_s > cold.consumers_per_s,
        "peer-fetch row must beat shared-FS-cold: {:.2} vs {:.2}",
        peer.consumers_per_s,
        cold.consumers_per_s
    );
    assert!(
        local.consumers_per_s >= peer.consumers_per_s,
        "local hits can't lose to peer fetches: {:.2} vs {:.2}",
        local.consumers_per_s,
        peer.consumers_per_s
    );
    assert!(peer.peer_gb > 0.0, "peer row must move bytes over links");
    assert!(
        cold.peer_gb == 0.0 && cold.fs_gb > 0.0,
        "cold row must restage through the FS only"
    );

    let mut report = Json::obj();
    report.set("bench", "diffusion");
    report.set("quick", quick);
    report.set("volumes", volumes);
    report.set("n_tasks", volumes * 4);
    report.set("dataset_mb", VOLUME_MB);
    report.set("executors", EXECUTORS);
    report.set("sim_sharedfs_tasks_per_s", sharedfs.tasks_per_s);
    report.set("sim_cache_hit_tasks_per_s", cached.tasks_per_s);
    report.set("sim_eviction_pressure_tasks_per_s", evict.tasks_per_s);
    report.set("sim_exec_faults_tasks_per_s", faults.tasks_per_s);
    report.set(
        "cache_hit_speedup",
        cached.tasks_per_s / sharedfs.tasks_per_s,
    );
    report.set("sharedfs_fs_gb", sharedfs.fs_gb);
    report.set("cache_hit_fs_gb", cached.fs_gb);
    report.set("cache_hit_hits", cached.stats.hits);
    report.set("cache_hit_misses", cached.stats.misses);
    report.set("evict_pressure_evictions", evict.stats.evictions);
    report.set("peer_dataset_mb", PEER_DS_MB);
    report.set("peer_consumers", PEER_CONSUMERS as u64);
    report.set("sim_peer_local_hit_tasks_per_s", local.consumers_per_s);
    report.set("sim_peer_fetch_tasks_per_s", peer.consumers_per_s);
    report.set("sim_peer_sharedfs_cold_tasks_per_s", cold.consumers_per_s);
    report.set("peer_fetch_fs_gb", peer.fs_gb);
    report.set("peer_fetch_peer_gb", peer.peer_gb);
    report.set("sharedfs_cold_fs_gb", cold.fs_gb);
    if let Some(hwm) = vm_hwm_bytes() {
        report.set("peak_rss_mb", hwm as f64 / 1e6);
    }
    let events = counters::global().snapshot();
    report.set("cache_hit_bytes", events.get("cache_hit_bytes"));
    report.set("cache_miss_bytes", events.get("cache_miss_bytes"));
    report.set("peer_transfer_bytes", events.get("peer_transfer_bytes"));
    report.set("sharedfs_transfer_bytes", events.get("sharedfs_transfer_bytes"));
    std::fs::write("BENCH_diffusion.json", report.render())
        .expect("write BENCH_diffusion.json");
    println!("\nwrote BENCH_diffusion.json");
}
