//! Scheduler experiment matrix (DESIGN.md §9.4): every pluggable DAG
//! scheduler swept over (workflow × site system), reporting virtual
//! makespan against the critical-path/area lower bound.
//!
//! Rows: one per (dag × system × scheduler) cell from
//! `gridswift::sim::experiment::run_matrix` — bag-of-tasks, fMRI, and
//! Montage shapes on a homogeneous pair and a heterogeneous pair of
//! sites, under adaptive (the production policy), HEFT, PEFT,
//! dynamic-list, min-queue, and round-robin.
//!
//! The JSON artifact carries `sim_sched_{dag}_{sched}_efficiency` keys
//! (lower_bound / makespan, higher is better, worst case across the
//! site systems) — deterministic virtual-time numbers, so CI gates the
//! adaptive/HEFT/PEFT cells via `scripts/bench_trend.py` (>20%
//! regression fails).
//!
//! Flags: `--quick` shrinks the DAGs for CI; `--smoke` runs a single
//! cell and skips the JSON artifact (debug-assertions CI smoke).

use gridswift::sim::experiment::{run_cell, run_matrix, summary_table, systems};
use gridswift::sim::Dag;
use gridswift::util::json::Json;
use gridswift::util::mem::vm_hwm_bytes;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let smoke = std::env::args().any(|a| a == "--smoke");

    println!("== DAG scheduler matrix ==\n");

    if smoke {
        // One cell with every debug_assert! live: a small bag under
        // HEFT (static plan + repair path) on the heterogeneous pair.
        let (system_name, sites) = systems().remove(1);
        let cell = run_cell(
            "bag",
            Dag::bag(48, "t", 1.0),
            system_name,
            sites,
            "heft",
            7,
        );
        println!("{}", summary_table(std::slice::from_ref(&cell)));
        assert!(cell.makespan_secs + 1e-9 >= cell.lower_bound_secs);
        return;
    }

    let cells = run_matrix(quick);
    println!("{}", summary_table(&cells));

    let mut report = Json::obj();
    report.set("bench", "schedulers");
    report.set("quick", quick);
    report.set("cells", cells.len() as u64);
    for c in &cells {
        assert!(
            c.makespan_secs + 1e-9 >= c.lower_bound_secs,
            "{}/{}/{}: makespan {} under bound {}",
            c.dag,
            c.system,
            c.scheduler,
            c.makespan_secs,
            c.lower_bound_secs
        );
        assert!(c.efficiency > 0.0 && c.efficiency <= 1.0 + 1e-9);
    }
    // Gated keys: worst-case efficiency across site systems per
    // (dag, scheduler) — one deterministic, higher-is-better number
    // each, independent of how many systems the matrix grows.
    let mut pairs: Vec<(&str, &str)> =
        cells.iter().map(|c| (c.dag, c.scheduler)).collect();
    pairs.sort_unstable();
    pairs.dedup();
    for (dag, sched) in pairs {
        let worst = cells
            .iter()
            .filter(|c| c.dag == dag && c.scheduler == sched)
            .map(|c| c.efficiency)
            .fold(f64::INFINITY, f64::min);
        report.set(&format!("sim_sched_{dag}_{sched}_efficiency"), worst);
    }
    if let Some(hwm) = vm_hwm_bytes() {
        report.set("peak_rss_mb", hwm as f64 / 1e6);
    }
    std::fs::write("BENCH_schedulers.json", report.render())
        .expect("write BENCH_schedulers.json");
    println!("wrote BENCH_schedulers.json");
}
