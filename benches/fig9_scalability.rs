//! Figure 9: system scalability — memory footprint per workflow node.
//!
//! The paper measures how many Karajan lightweight threads (~800 B each)
//! and Swift workflow nodes (~3.2 KB each: futures + dataset objects +
//! procedure metadata) fit in a given heap. We build the same two
//! structures — bare continuations on the control queue, and full dataflow
//! nodes (future + struct slots + call-path key) — and measure RSS growth
//! per node, then report nodes-per-32MB/1GB like the paper.

use std::sync::Arc;

use gridswift::karajan::{ArraySlot, DataFuture, Slot};
use gridswift::metrics::Table;
use gridswift::util::mem::rss_bytes;
use gridswift::xdtm::Value;

/// Measure bytes/node for `n` instances built by `f` (keeps them alive).
fn bytes_per<T>(n: usize, f: impl Fn(usize) -> T) -> f64 {
    // Warm-up allocation to stabilize the allocator.
    let _warm: Vec<u64> = (0..4096).map(|i| i as u64).collect();
    let before = rss_bytes().unwrap_or(0);
    let items: Vec<T> = (0..n).map(f).collect();
    let after = rss_bytes().unwrap_or(0);
    drop(items);
    (after.saturating_sub(before)) as f64 / n as f64
}

fn main() {
    println!("== Figure 9: memory footprint per workflow node ==\n");
    let n = 200_000;

    // "Karajan lightweight thread": a pending continuation closure.
    let lw = bytes_per(n, |i| -> Box<dyn FnOnce() + Send> {
        Box::new(move || {
            let _ = i;
        })
    });

    // "Swift workflow node": output future + a Volume-like struct slot +
    // the deterministic call-path key + army entry (paper: ~3.2 KB in
    // Java; ours is native Rust so expect far less).
    let arr = Arc::new(ArraySlot::new());
    let arr2 = Arc::clone(&arr);
    let node = bytes_per(n, move |i| {
        let fut = DataFuture::new();
        let mut fields = std::collections::BTreeMap::new();
        fields.insert("img".to_string(), Slot::Future(DataFuture::new()));
        fields.insert("hdr".to_string(), Slot::Future(DataFuture::new()));
        let slot = Slot::Struct(Arc::new(fields));
        let key = format!("main/fmri_wf@0/reorientRun@0[{i}]/reorient");
        arr2.insert(i, slot.clone()).ok();
        (fut, slot, key)
    });
    let _ = arr;

    let mut t = Table::new(&[
        "Structure",
        "bytes/node",
        "nodes @32MB",
        "nodes @1GB",
        "paper bytes",
        "paper @32MB",
    ]);
    t.row(&[
        "lightweight thread (Karajan)".into(),
        format!("{lw:.0}"),
        format!("{:.0}", 32e6 / lw.max(1.0)),
        format!("{:.0}", 1e9 / lw.max(1.0)),
        "800".into(),
        "40000".into(),
    ]);
    t.row(&[
        "workflow node (Swift)".into(),
        format!("{node:.0}"),
        format!("{:.0}", 32e6 / node.max(1.0)),
        format!("{:.0}", 1e9 / node.max(1.0)),
        "3200".into(),
        "4000(32MB)/160K(1GB)".into(),
    ]);
    t.print();

    // Scale demonstration: build 1M dataflow nodes and resolve them.
    println!("\nscale check: building 1,000,000 futures...");
    let t0 = std::time::Instant::now();
    let big: Vec<DataFuture> = (0..1_000_000).map(|_| DataFuture::new()).collect();
    for (i, f) in big.iter().enumerate().step_by(1000) {
        f.set(Value::Int(i as i64)).unwrap();
    }
    println!(
        "  1M futures built (+1000 resolved) in {:.2}s; rss now {:.0} MB",
        t0.elapsed().as_secs_f64(),
        rss_bytes().unwrap_or(0) as f64 / 1e6
    );
    println!(
        "\nshape check: native nodes are well under the paper's JVM\n\
         footprints, so the paper's 160K-nodes-in-1GB bound is exceeded\n\
         by more than an order of magnitude."
    );
}
