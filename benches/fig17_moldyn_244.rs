//! Figures 17/18 + §5.4.3: the 244-molecule MolDyn run with DRP, vs the
//! 50-molecule GRAM/PBS attempt.
//!
//! Paper: 20497 jobs, ~900 CPU-hours, completing in 15091 s on up to 216
//! processors — 206.9x speedup at 99.8% efficiency; GRAM+PBS only managed
//! 25.3x on 50 molecules (submission throttled to 1 job per 5 s, whole-
//! node allocation wasting the second processor).

use gridswift::metrics::Table;
use gridswift::sim::driver::{Driver, Mode};
use gridswift::sim::falkon_model::{DrpPolicy, FalkonConfig};
use gridswift::sim::lrm::{GramConfig, LrmConfig};
use gridswift::sim::Dag;
use gridswift::util::time::secs;
use gridswift::util::DetRng;

fn main() {
    println!("== Figures 17/18: MolDyn 244 molecules (Falkon+DRP) vs 50 (GRAM/PBS) ==\n");

    // Falkon + DRP, 244 molecules.
    let mut rng = DetRng::new(17);
    let dag = Dag::moldyn(244, &mut rng);
    println!(
        "workflow: {} jobs, {:.0} CPU-hours total service (paper: 20497 jobs, <=957 CPU-hours)",
        dag.len(),
        dag.total_service_secs() / 3600.0
    );
    let total_service = dag.total_service_secs();
    let mut cfg = FalkonConfig::default();
    cfg.drp = DrpPolicy {
        tasks_per_executor: 1,
        max_executors: 216,
        min_executors: 0,
        allocation_latency: secs(81.0),
        idle_timeout: secs(120.0),
        check_interval: secs(5.0),
        chunk: 2,
    };
    let falkon = Driver::new(dag, Mode::Falkon { cfg }, 17).run();

    // GRAM/PBS, 50 molecules (paper could not complete 244): submission
    // throttle 1 job / 5 s, whole-node allocation.
    let mut rng2 = DetRng::new(18);
    let dag50 = Dag::moldyn(50, &mut rng2);
    let service50 = dag50.total_service_secs();
    let gram = Driver::new(
        dag50,
        Mode::GramLrm {
            lrm: LrmConfig::pbs_whole_node(100),
            gram: GramConfig { submit_cost: secs(1.0), throttle_interval: secs(5.0) },
        },
        18,
    )
    .run();

    let mut t = Table::new(&["Metric", "Falkon 244-mol (ours)", "Paper", "GRAM/PBS 50-mol (ours)", "Paper"]);
    t.row(&[
        "jobs".into(),
        falkon.timeline.len().to_string(),
        "20497".into(),
        gram.timeline.len().to_string(),
        "4201".into(),
    ]);
    t.row(&[
        "makespan".into(),
        format!("{:.0}s", falkon.makespan_secs),
        "15091s".into(),
        format!("{:.0}s", gram.makespan_secs),
        "25292s".into(),
    ]);
    t.row(&[
        "peak CPUs".into(),
        falkon.peak_resources.to_string(),
        "216".into(),
        "100 (whole-node)".into(),
        "200".into(),
    ]);
    t.row(&[
        "speedup".into(),
        format!("{:.1}x", falkon.speedup(total_service)),
        "206.9x".into(),
        format!("{:.1}x", gram.speedup(service50)),
        "25.3x".into(),
    ]);
    t.row(&[
        "allocation efficiency".into(),
        format!("{:.2}%", falkon.allocation_efficiency() * 100.0),
        "99.8%".into(),
        "-".into(),
        "-".into(),
    ]);
    t.print();

    println!("\nshape checks:");
    println!(
        "  Falkon speedup / GRAM speedup = {:.1}x (paper: 206.9/25.3 = 8.2x)",
        falkon.speedup(total_service) / gram.speedup(service50)
    );
    println!(
        "  queue peaked at {} tasks; executors peaked at {}",
        falkon.peak_queue, falkon.peak_resources
    );
}
