//! Ablations over the design choices DESIGN.md §5 calls out:
//! clustering bundle size, DRP policy, dispatcher cost sensitivity, and
//! load-balancing policy.

use gridswift::metrics::Table;
use gridswift::sim::driver::{Driver, Mode};
use gridswift::sim::falkon_model::{DrpPolicy, FalkonConfig};
use gridswift::sim::lrm::{GramConfig, LrmConfig};
use gridswift::sim::Dag;
use gridswift::util::time::secs;
use gridswift::util::DetRng;

fn fmri_dag(vols: usize, seed: u64) -> Dag {
    let mut rng = DetRng::new(seed);
    Dag::fmri(vols, [3.0, 3.0, 5.0, 4.0], &mut rng)
}

fn main() {
    println!("== Ablations ==\n");

    // 1. Clustering bundle size (paper §5.4.1: groups of 4/6/8/10 were
    // within 10%).
    println!("-- clustering bundle size (fMRI 120 volumes, GRAM+PBS 62 nodes) --");
    let mut t = Table::new(&["Bundle", "makespan", "vs best"]);
    let mut results = Vec::new();
    for bundle in [1usize, 4, 8, 15, 30, 60, 120] {
        let o = Driver::new(
            fmri_dag(120, 1),
            Mode::GramCluster {
                lrm: LrmConfig::pbs(62),
                gram: GramConfig::gt2(),
                bundle,
                window: secs(5.0),
            },
            1,
        )
        .run();
        results.push((bundle, o.makespan_secs));
    }
    let best = results.iter().map(|r| r.1).fold(f64::MAX, f64::min);
    for (bundle, m) in &results {
        t.row(&[
            bundle.to_string(),
            format!("{m:.0}s"),
            format!("{:+.0}%", (m / best - 1.0) * 100.0),
        ]);
    }
    t.print();
    println!("  paper: bundle sizes 4-10 within ~10%; size 1 = unclustered worst case\n");

    // 2. DRP policy on MolDyn 8 molecules.
    println!("-- DRP policy (MolDyn 8 molecules) --");
    let mut t = Table::new(&["Policy", "makespan", "alloc efficiency", "peak execs"]);
    let policies: Vec<(&str, DrpPolicy)> = vec![
        ("dynamic (paper)", DrpPolicy {
            tasks_per_executor: 1,
            max_executors: 64,
            min_executors: 0,
            allocation_latency: secs(81.0),
            idle_timeout: secs(120.0),
            check_interval: secs(5.0),
            chunk: 2,
        }),
        ("static pool 64", {
            let mut p = DrpPolicy::static_pool(64);
            p.allocation_latency = secs(81.0);
            p
        }),
        ("conservative (4 tasks/exec)", DrpPolicy {
            tasks_per_executor: 4,
            max_executors: 64,
            min_executors: 0,
            allocation_latency: secs(81.0),
            idle_timeout: secs(120.0),
            check_interval: secs(5.0),
            chunk: 2,
        }),
    ];
    for (name, drp) in policies {
        let mut rng = DetRng::new(2);
        let dag = Dag::moldyn(8, &mut rng);
        let cfg = FalkonConfig { drp, ..Default::default() };
        let o = Driver::new(dag, Mode::Falkon { cfg }, 2).run();
        t.row(&[
            name.to_string(),
            format!("{:.0}s", o.makespan_secs),
            format!("{:.1}%", o.allocation_efficiency() * 100.0),
            o.peak_resources.to_string(),
        ]);
    }
    t.print();
    println!("  dynamic provisioning trades a little makespan for much less wasted allocation\n");

    // 3. Dispatcher cost sensitivity (fig6-style point at 1s tasks).
    println!("-- dispatch cost sensitivity (64x 1s tasks, 64 executors) --");
    let mut t = Table::new(&["dispatch cost", "efficiency"]);
    for ms in [0.5f64, 1.0, 2.053, 4.0, 8.0, 16.0] {
        let mut cfg = FalkonConfig::default();
        cfg.dispatch_cost = (ms * 1000.0) as u64;
        cfg.drp = DrpPolicy::static_pool(64);
        cfg.drp.allocation_latency = 0;
        let o = Driver::new(Dag::bag(64, "t", 1.0), Mode::Falkon { cfg }, 3).run();
        t.row(&[
            format!("{ms}ms"),
            format!("{:.1}%", o.timeline.efficiency(64) * 100.0),
        ]);
    }
    t.print();
    println!("  the paper's 2ms/task dispatcher is comfortably off the knee at 1s tasks\n");

    // 4. Executor-side overhead (sandbox) sensitivity.
    println!("-- executor overhead (sandbox) sensitivity (64x 1s tasks) --");
    let mut t = Table::new(&["overhead", "efficiency"]);
    for ms in [0u64, 10, 45, 100, 250] {
        let mut cfg = FalkonConfig::default();
        cfg.executor_overhead = ms * 1000;
        cfg.drp = DrpPolicy::static_pool(64);
        cfg.drp.allocation_latency = 0;
        let o = Driver::new(Dag::bag(64, "t", 1.0), Mode::Falkon { cfg }, 4).run();
        t.row(&[
            format!("{ms}ms"),
            format!("{:.1}%", o.timeline.efficiency(64) * 100.0),
        ]);
    }
    t.print();
    println!("  per-task sandbox cost dominates short-task efficiency (the Swift-vs-direct gap in Fig 12)");
}
