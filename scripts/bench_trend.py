#!/usr/bin/env python3
"""Compare the current BENCH_dispatch.json against the previous run.

Usage: bench_trend.py PREV_JSON CURRENT_JSON [--max-regress 0.20]

Fails (exit 1) when a tracked tasks/s metric regressed by more than
--max-regress relative to the previous run. A missing/unreadable
previous file is not an error (first run, expired artifact): the check
passes with a note so the pipeline stays green on fresh branches.
Improvements and regressions within tolerance are reported for the log.
"""

import argparse
import json
import sys

# Metrics tracked for regression: (label, path into the JSON object).
TRACKED = [
    ("single-submit tasks/s", ("single_submit", "tasks_per_s")),
    ("batched-submit tasks/s", ("batched_submit", "tasks_per_s")),
]


def lookup(obj, path):
    for key in path:
        if not isinstance(obj, dict) or key not in obj:
            return None
        obj = obj[key]
    return obj if isinstance(obj, (int, float)) else None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("prev")
    ap.add_argument("current")
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="maximum allowed fractional drop (default 0.20)")
    args = ap.parse_args()

    try:
        with open(args.prev) as f:
            prev = json.load(f)
    except (OSError, ValueError) as e:
        print(f"no previous bench to compare ({e}); passing")
        return 0

    try:
        with open(args.current) as f:
            cur = json.load(f)
    except (OSError, ValueError) as e:
        print(f"ERROR: current bench unreadable: {e}")
        return 1

    # Quick-mode runs use smaller task counts; rates are still
    # comparable, but flag mismatched modes in the log.
    if prev.get("quick") != cur.get("quick"):
        print(f"note: mode mismatch (prev quick={prev.get('quick')}, "
              f"cur quick={cur.get('quick')}); comparing anyway")

    failed = False
    for label, path in TRACKED:
        p, c = lookup(prev, path), lookup(cur, path)
        if c is None:
            # The current bench must always emit every tracked key; a
            # silent skip here would disable the gate on a key rename.
            print(f"  {label}: MISSING from current bench output")
            failed = True
            continue
        if p is None or p <= 0:
            print(f"  {label}: no previous value (prev={p}); skipping")
            continue
        delta = (c - p) / p
        mark = "OK"
        if delta < -args.max_regress:
            mark = "REGRESSION"
            failed = True
        print(f"  {label}: {p:.0f} -> {c:.0f} ({delta:+.1%}) {mark}")

    if failed:
        print(f"FAIL: a tracked metric is missing or dropped more than "
              f"{args.max_regress:.0%} vs the previous run")
        return 1
    print("bench trend OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
