#!/usr/bin/env python3
"""Compare a current bench JSON against the previous run's.

Usage: bench_trend.py PREV_JSON CURRENT_JSON [--max-regress 0.20]

Fails (exit 1) when a tracked tasks/s metric regressed by more than
--max-regress relative to the previous run. A missing/unreadable
previous file is not an error (first run, expired artifact): the check
passes with a note so the pipeline stays green on fresh branches.
Improvements and regressions within tolerance are reported for the log.

The tracked key set is selected by the report's "bench" field, so the
same gate covers BENCH_dispatch.json (falkon_micro) and
BENCH_fig12.json (fig12_throughput).
"""

import argparse
import json
import sys

# Metrics tracked per bench id: (label, path into the JSON object,
# gated). Gated metrics fail the run on a >max-regress drop; ungated
# ones must still be present (a silent key rename would disable the
# gate) but only report their delta — real-machine throughput on shared
# CI runners swings too much run-to-run to block PRs on, while the
# virtual-time sim numbers are deterministic and gate tightly.
TRACKED_BY_BENCH = {
    "falkon_micro": [
        ("single-submit tasks/s", ("single_submit", "tasks_per_s"), True),
        ("batched-submit tasks/s", ("batched_submit", "tasks_per_s"), True),
        # Wire codec rows are pure CPU (no sockets, best-of-3): stable
        # enough to gate. The binary row is the one the sim's
        # BIN_TEXT_COST_RATIO is calibrated against.
        ("binary codec tasks/s", ("real_binary_codec_tasks_per_s",), True),
        ("text codec tasks/s", ("real_text_codec_tasks_per_s",), False),
        # End-to-end TCP rates ride shared-runner network stacks:
        # present-or-fail, but report-only deltas.
        ("binary TCP tasks/s", ("real_binary_tcp_tasks_per_s",), True),
        ("text TCP tasks/s", ("real_text_tcp_tasks_per_s",), False),
        # Queue contention sweep (best-of-3 on a single shard). The
        # lock-free rows are the tentpole claim; the Mutex baseline is
        # context.
        ("lock-free queue 1w ops/s",
         ("queue_contention_lockfree_1w_ops_per_s",), True),
        ("lock-free queue 8w ops/s",
         ("queue_contention_lockfree_8w_ops_per_s",), True),
        ("mutex queue 1w ops/s",
         ("queue_contention_mutex_1w_ops_per_s",), False),
        ("mutex queue 8w ops/s",
         ("queue_contention_mutex_8w_ops_per_s",), False),
        # Observability ride-alongs: memory high-water mark and global
        # wire/dispatch event totals. Report-only (RSS swings with the
        # runner image; counts scale with --quick), but present-or-fail
        # so a key rename can't silently drop them.
        ("peak RSS MB", ("peak_rss_mb",), False),
        ("frames encoded", ("frames_encoded",), False),
        ("frames decoded", ("frames_decoded",), False),
        ("tasks dispatched", ("tasks_dispatched",), False),
    ],
    "fig12_throughput": [
        ("falkon in-process tasks/s", ("falkon_inproc_tasks_per_s",), False),
        ("falkon TCP framed tasks/s", ("falkon_tcp_framed_tasks_per_s",), False),
        ("falkon TCP binary tasks/s", ("falkon_tcp_binary_tasks_per_s",), False),
        ("WAN sim framed tasks/s", ("sim_wan_framed_tasks_per_s",), True),
        ("WAN sim line-per-task tasks/s",
         ("sim_wan_line_per_task_tasks_per_s",), True),
        ("WAN sim binary tasks/s", ("sim_wan_binary_tasks_per_s",), True),
        ("peak RSS MB", ("peak_rss_mb",), False),
        ("frames encoded", ("frames_encoded",), False),
        ("frames decoded", ("frames_decoded",), False),
    ],
    # All diffusion rows are deterministic virtual-time sims: gate them
    # all (a >20% drop means a code change, not runner noise).
    "diffusion": [
        ("shared-FS-every-time tasks/s", ("sim_sharedfs_tasks_per_s",), True),
        ("cache-hit tasks/s", ("sim_cache_hit_tasks_per_s",), True),
        ("eviction-pressure tasks/s",
         ("sim_eviction_pressure_tasks_per_s",), True),
        ("executor-faults tasks/s", ("sim_exec_faults_tasks_per_s",), True),
        # Peer-transfer-network rows (local-hit / peer-fetch /
        # shared-FS-cold fan-out trio): also deterministic virtual time.
        ("peer local-hit consumers/s",
         ("sim_peer_local_hit_tasks_per_s",), True),
        ("peer-fetch consumers/s", ("sim_peer_fetch_tasks_per_s",), True),
        ("peer shared-FS-cold consumers/s",
         ("sim_peer_sharedfs_cold_tasks_per_s",), True),
        ("peak RSS MB", ("peak_rss_mb",), False),
        ("cache hit bytes", ("cache_hit_bytes",), False),
        ("cache miss bytes", ("cache_miss_bytes",), False),
        ("peer transfer bytes", ("peer_transfer_bytes",), False),
        ("shared-FS transfer bytes", ("sharedfs_transfer_bytes",), False),
    ],
    # Scheduler matrix efficiencies (lower_bound / makespan, higher is
    # better): pure virtual-time numbers, bit-deterministic per cell, so
    # any drop is a policy change. Gate the production policy (adaptive)
    # and the rank-based schedulers on the bag + fMRI workloads; the
    # Montage cells and the naive baselines are report-only context.
    "schedulers": [
        ("bag adaptive efficiency", ("sim_sched_bag_adaptive_efficiency",), True),
        ("bag HEFT efficiency", ("sim_sched_bag_heft_efficiency",), True),
        ("bag PEFT efficiency", ("sim_sched_bag_peft_efficiency",), True),
        ("fMRI adaptive efficiency",
         ("sim_sched_fmri_adaptive_efficiency",), True),
        ("fMRI HEFT efficiency", ("sim_sched_fmri_heft_efficiency",), True),
        ("fMRI PEFT efficiency", ("sim_sched_fmri_peft_efficiency",), True),
        ("Montage adaptive efficiency",
         ("sim_sched_montage_adaptive_efficiency",), False),
        ("Montage HEFT efficiency",
         ("sim_sched_montage_heft_efficiency",), False),
        ("Montage PEFT efficiency",
         ("sim_sched_montage_peft_efficiency",), False),
        ("bag dynamic-list efficiency",
         ("sim_sched_bag_dynamic-list_efficiency",), False),
        ("bag min-queue efficiency",
         ("sim_sched_bag_min-queue_efficiency",), False),
        ("bag round-robin efficiency",
         ("sim_sched_bag_round-robin_efficiency",), False),
        ("peak RSS MB", ("peak_rss_mb",), False),
    ],
    # Sim-core engine speed: wall-clock rates of a fixed deterministic
    # workload (same events, same schedule, every run), so a >20% drop
    # is an engine change, not workload noise. Peak RSS is report-only:
    # allocator/page behavior swings with the runner image.
    "simcore": [
        ("queue-churn events/s", ("sim_queue_events_per_s",), True),
        ("1M-task DAG tasks/s", ("sim_dag_tasks_per_s",), True),
        ("1M-task DAG events/s", ("sim_dag_events_per_s",), True),
        ("1M-task DAG peak RSS MB", ("peak_rss_mb",), False),
        # Fully-lit (counters + spans) engine rate: gated like the other
        # deterministic-workload rows, so telemetry cost creep fails CI.
        ("telemetry-lit events/s", ("telemetry_churn_events_per_s",), True),
        # Overhead percentage is lower-is-better — the drop-gate's
        # polarity is wrong for it, so it is present-or-fail only (the
        # bench itself asserts the <5% budget).
        ("telemetry overhead %", ("telemetry_overhead_pct",), False),
    ],
}


def lookup(obj, path):
    for key in path:
        if not isinstance(obj, dict) or key not in obj:
            return None
        obj = obj[key]
    return obj if isinstance(obj, (int, float)) else None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("prev")
    ap.add_argument("current")
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="maximum allowed fractional drop (default 0.20)")
    args = ap.parse_args()

    try:
        with open(args.prev) as f:
            prev = json.load(f)
    except (OSError, ValueError) as e:
        print(f"no previous bench to compare ({e}); passing")
        return 0

    try:
        with open(args.current) as f:
            cur = json.load(f)
    except (OSError, ValueError) as e:
        print(f"ERROR: current bench unreadable: {e}")
        return 1

    bench = cur.get("bench")
    tracked = TRACKED_BY_BENCH.get(bench)
    if tracked is None:
        print(f"ERROR: unknown bench id {bench!r} in current report; "
              f"known: {sorted(TRACKED_BY_BENCH)}")
        return 1
    if prev.get("bench") not in (None, bench):
        print(f"note: comparing across bench ids (prev={prev.get('bench')!r}, "
              f"cur={bench!r}); previous values will likely be missing")

    # Quick-mode runs use smaller task counts; rates are still
    # comparable, but flag mismatched modes in the log.
    if prev.get("quick") != cur.get("quick"):
        print(f"note: mode mismatch (prev quick={prev.get('quick')}, "
              f"cur quick={cur.get('quick')}); comparing anyway")

    failed = False
    for label, path, gated in tracked:
        p, c = lookup(prev, path), lookup(cur, path)
        if c is None:
            # The current bench must always emit every tracked key; a
            # silent skip here would disable the gate on a key rename.
            print(f"  {label}: MISSING from current bench output")
            failed = True
            continue
        if p is None or p <= 0:
            print(f"  {label}: no previous value (prev={p}); skipping")
            continue
        delta = (c - p) / p
        mark = "OK"
        if delta < -args.max_regress:
            if gated:
                mark = "REGRESSION"
                failed = True
            else:
                mark = "regressed (report-only)"
        # .4g: tasks/s rates print as integers-ish, efficiency ratios
        # (0 < x <= 1) keep their significant digits.
        print(f"  {label}: {p:.4g} -> {c:.4g} ({delta:+.1%}) {mark}")

    if failed:
        print(f"FAIL: a tracked metric is missing or dropped more than "
              f"{args.max_regress:.0%} vs the previous run")
        return 1
    print("bench trend OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
