//! Standalone Falkon service over TCP: start the service, submit a batch
//! of sleep-0 tasks through the network endpoint, and report dispatch
//! throughput (the paper's §4 microbenchmark shape). Pass `--serve
//! <addr>` to leave the service running for external clients.
//!
//! ```sh
//! cargo run --release --example falkon_service            # benchmark mode
//! cargo run --release --example falkon_service -- --serve 127.0.0.1:9123
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use gridswift::apps::AppRegistry;
use gridswift::falkon::{
    FalkonClient, FalkonService, FalkonServiceConfig, FalkonTcpServer, RealDrpPolicy,
    TaskSpec,
};
use gridswift::telemetry::spans;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let registry = Arc::new(AppRegistry::standard());
    let svc = FalkonService::start(
        FalkonServiceConfig {
            drp: RealDrpPolicy::static_pool(8),
            executor_overhead: std::time::Duration::ZERO,
        },
        registry.runner(),
    );

    if let Some(pos) = args.iter().position(|a| a == "--serve") {
        let addr = args.get(pos + 1).map(|s| s.as_str()).unwrap_or("127.0.0.1:9123");
        let server = FalkonTcpServer::start(Arc::clone(&svc), addr)?;
        println!("falkon service listening on {}", server.addr());
        println!(
            "protocol: SUBMIT <id> <executable> [args...] | SUBMITB <n> + n task lines | STATS | QUIT"
        );
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }

    // Benchmark mode: in-process endpoint, pipelined submissions. Span
    // recording is on for this leg so the run doubles as a live trace
    // capture (exported as Chrome-trace JSON below).
    spans::set_enabled(true);
    let server = FalkonTcpServer::start(Arc::clone(&svc), "127.0.0.1:0")?;
    println!("== Falkon service microbenchmark (TCP endpoint) ==");
    let mut client = FalkonClient::connect(server.addr())?;
    let n = 10_000u64;
    let t0 = Instant::now();
    for i in 0..n {
        client.submit(i, "sleep0", &[])?;
    }
    let mut ok = 0u64;
    for _ in 0..n {
        if client.next_result()?.ok {
            ok += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{ok}/{n} tasks through TCP submit->dispatch->notify in {dt:.2}s = {:.0} tasks/s",
        n as f64 / dt
    );
    // Export the traced leg before the framed run reuses the rings.
    spans::set_enabled(false);
    let tasks = spans::assemble(&spans::global().snapshot());
    let trace_path = std::path::Path::new("target").join("TRACE_falkon_service.json");
    std::fs::create_dir_all("target")?;
    std::fs::write(&trace_path, spans::chrome_trace(&tasks).render())?;
    println!(
        "wrote {} lifecycle traces ({} events dropped) to {} — load in chrome://tracing or Perfetto",
        tasks.len(),
        spans::global().dropped(),
        trace_path.display()
    );

    // Framed mode: the same load as SUBMITB frames of 256 (one write and
    // one server-side queue push per frame, coalesced DONEB acks).
    let t0 = Instant::now();
    let mut i = n;
    while i < 2 * n {
        let hi = (i + 256).min(2 * n);
        let frame: Vec<TaskSpec> = (i..hi)
            .map(|id| TaskSpec { id, executable: "sleep0".into(), args: vec![] })
            .collect();
        client.submit_batch(&frame)?;
        i = hi;
    }
    let mut ok_framed = 0u64;
    for _ in 0..n {
        if client.next_result()?.ok {
            ok_framed += 1;
        }
    }
    let dtf = t0.elapsed().as_secs_f64();
    println!(
        "{ok_framed}/{n} tasks as SUBMITB x256 frames in {dtf:.2}s = {:.0} tasks/s",
        n as f64 / dtf
    );
    println!("(paper: Falkon sustains 487 tasks/s; Figure 12 measured 120/s end-to-end)");
    let (submitted, completed, failed, queue, execs) = client.stats()?;
    println!(
        "service stats: submitted={submitted} completed={completed} failed={failed} queued={queue} executors={execs}"
    );
    println!("falkon_service OK");
    Ok(())
}
