//! End-to-end driver (the repository's headline validation run): the
//! paper's fMRI spatial-normalization workflow (Figure 1) on a synthetic
//! study, executed through the full stack — SwiftScript -> Karajan engine
//! -> Falkon service -> PJRT-executed Pallas kernels — with pipelining
//! on/off comparison (Figure 10's effect) and a quality check that the
//! normalization actually corrected the simulated head motion.
//!
//! ```sh
//! make artifacts && cargo run --release --example fmri_pipeline [volumes]
//! ```

use anyhow::{bail, Result};
use gridswift::apps::{exec, fmri};
use gridswift::metrics::plot::gantt;
use gridswift::runtime::{self, Tensor};
use gridswift::stack::{build, ProviderKind, StackOptions};
use gridswift::swiftscript::compile;

fn main() -> Result<()> {
    let volumes: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().unwrap_or(24))
        .unwrap_or(24);
    if !runtime::default_artifact_dir().join("manifest.txt").exists() {
        bail!("artifacts missing — run `make artifacts` first");
    }

    let wd = std::env::temp_dir().join("gridswift_fmri_example");
    let _ = std::fs::remove_dir_all(&wd);
    std::fs::create_dir_all(&wd)?;
    let study = wd.join("study");
    println!("== fMRI spatial normalization ({volumes} volumes) ==");
    fmri::generate_study(&study, "bold1", volumes, 2026)?;
    println!(
        "generated study: {volumes} volumes of {:?} f32 (~{} KB each)",
        exec::VOLUME,
        exec::VOLUME.iter().product::<usize>() * 4 / 1024
    );

    let mut results = Vec::new();
    for pipelining in [true, false] {
        let outdir = wd.join(format!("norm_pipe_{pipelining}"));
        let src = fmri::workflow_source(&study, &outdir, "bold1");
        let prog = compile(&src)?;
        let stack = build(StackOptions {
            provider: ProviderKind::Falkon,
            workers: 8,
            workdir: wd.join(format!("work_{pipelining}")),
            pipelining,
            ..Default::default()
        })?;
        let t0 = std::time::Instant::now();
        let report = stack.engine.run(&prog)?;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "\npipelining={pipelining}: {} tasks in {dt:.2}s ({:.1} tasks/s)",
            report.executed,
            report.executed as f64 / dt
        );
        print!(
            "{}",
            gantt(
                &format!("stage windows (pipelining={pipelining})"),
                &report.timeline.stage_windows(),
                48
            )
        );
        results.push((pipelining, dt, outdir));
    }
    let (_, t_pipe, outdir) = &results[0];
    let (_, t_stage, _) = &results[1];
    println!(
        "\npipelining effect: {:.2}s vs {:.2}s staged ({:.0}% reduction; paper: 21%)",
        t_pipe,
        t_stage,
        (1.0 - t_pipe / t_stage) * 100.0
    );

    // Validation: normalized volumes must be mutually closer than the
    // motion-corrupted inputs.
    let read = |dir: &std::path::Path, pfx: &str, i: usize| -> Result<Tensor> {
        Ok(Tensor::read_raw(
            &dir.join(format!("{pfx}_{i:04}.img")),
            &exec::VOLUME,
        )?)
    };
    let dist = |a: &Tensor, b: &Tensor| -> f64 {
        a.data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| ((x - y) * (x - y)) as f64)
            .sum()
    };
    let mut raw = 0.0;
    let mut norm = 0.0;
    let n_check = volumes.min(8);
    for i in 1..n_check {
        raw += dist(&read(&study, "bold1", 0)?, &read(&study, "bold1", i)?);
        norm += dist(
            &read(outdir, "sbold1", 0)?,
            &read(outdir, "sbold1", i)?,
        );
    }
    println!(
        "motion-correction quality: inter-volume SSD {:.1} -> {:.1} ({:.0}% reduction)",
        raw,
        norm,
        (1.0 - norm / raw) * 100.0
    );
    if norm >= raw {
        bail!("normalization did not reduce inter-volume distance");
    }
    println!("fmri_pipeline OK");
    Ok(())
}
