//! Montage mosaic with runtime-determined workflow structure (paper
//! §3.6): the overlap table is *computed during the run* by mOverlaps,
//! mapped through csv_mapper, and fanned out — the workflow's diff stage
//! width is unknown until then.
//!
//! ```sh
//! make artifacts && cargo run --release --example montage_mosaic [side]
//! ```

use anyhow::{bail, Result};
use gridswift::apps::{exec, montage};
use gridswift::runtime::{self, Tensor};
use gridswift::stack::{build, ProviderKind, StackOptions};
use gridswift::swiftscript::compile;

fn main() -> Result<()> {
    let side: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().unwrap_or(2))
        .unwrap_or(2);
    if !runtime::default_artifact_dir().join("manifest.txt").exists() {
        bail!("artifacts missing — run `make artifacts` first");
    }
    let wd = std::env::temp_dir().join("gridswift_montage_example");
    let _ = std::fs::remove_dir_all(&wd);
    let survey = wd.join("survey");
    let out = wd.join("out");
    std::fs::create_dir_all(&out)?;

    println!("== Montage mosaic ({side}x{side} plates) ==");
    let nplates = montage::generate_survey(&survey, side, 7)?;
    let expected_pairs = montage::expected_overlaps(side);
    println!(
        "survey: {nplates} plates of {:?} (~{} MB each), {expected_pairs} overlapping pairs expected",
        exec::IMAGE,
        exec::IMAGE.iter().product::<usize>() * 4 / (1024 * 1024)
    );

    let src = montage::workflow_source(&survey, &out);
    let prog = compile(&src)?;
    let stack = build(StackOptions {
        provider: ProviderKind::Falkon,
        workers: 8,
        workdir: wd.join("work"),
        provenance: true,
        ..Default::default()
    })?;
    let t0 = std::time::Instant::now();
    let report = stack.engine.run(&prog)?;
    let dt = t0.elapsed().as_secs_f64();

    println!("\nexecuted {} tasks in {dt:.2}s:", report.executed);
    for (stage, recs) in report.timeline.by_stage() {
        println!("  {stage:<12} x{}", recs.len());
    }
    let diff_count = report
        .timeline
        .records
        .iter()
        .filter(|r| r.stage == "mDiffFit")
        .count();
    println!(
        "dynamic fan-out: {diff_count} mDiffFit tasks (discovered at runtime; expected {expected_pairs})"
    );
    if diff_count != expected_pairs {
        bail!("overlap fan-out mismatch");
    }

    let mosaic = Tensor::read_raw(&out.join("mosaic.img"), &exec::IMAGE)?;
    let peak = mosaic.data.iter().cloned().fold(f32::MIN, f32::max);
    let mean = mosaic.data.iter().sum::<f32>() / mosaic.data.len() as f32;
    println!("mosaic written: peak {peak:.2}, mean {mean:.3}");

    if let Some(vdc) = &stack.vdc {
        // Provenance: how was the mosaic computed?
        let lineage = vdc.lineage(&out.join("mosaic.img"));
        println!(
            "provenance: mosaic derives from {} recorded invocations",
            lineage.len()
        );
    }
    println!("montage_mosaic OK");
    Ok(())
}
