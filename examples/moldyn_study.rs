//! MolDyn free-energy study (paper §5.4.3) with dynamic resource
//! provisioning: executors are acquired on demand as the per-molecule
//! fan-outs hit the Falkon queue and released when idle.
//!
//! ```sh
//! make artifacts && cargo run --release --example moldyn_study [molecules] [fan]
//! ```

use anyhow::{bail, Result};
use gridswift::apps::moldyn;
use gridswift::runtime;
use gridswift::stack::{build, ProviderKind, StackOptions};
use gridswift::swiftscript::compile;

fn main() -> Result<()> {
    let molecules: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().unwrap_or(3))
        .unwrap_or(3);
    let fan: usize = std::env::args()
        .nth(2)
        .map(|s| s.parse().unwrap_or(12))
        .unwrap_or(12);
    if !runtime::default_artifact_dir().join("manifest.txt").exists() {
        bail!("artifacts missing — run `make artifacts` first");
    }
    let wd = std::env::temp_dir().join("gridswift_moldyn_example");
    let _ = std::fs::remove_dir_all(&wd);
    let lib = wd.join("library");

    println!("== MolDyn study: {molecules} molecules, fan-out {fan} ==");
    moldyn::generate_library(&lib, molecules, fan, 11)?;
    let expected = moldyn::expected_tasks(molecules, fan);
    println!("workflow: {expected} jobs (1 + N x (fan + 7); paper ran 1 + 84N)");

    let src = moldyn::workflow_source(&lib, &wd);
    let prog = compile(&src)?;
    let stack = build(StackOptions {
        provider: ProviderKind::FalkonDrp,
        workers: 8,
        workdir: wd.join("work"),
        ..Default::default()
    })?;
    let svc = stack.falkon.clone().unwrap();
    println!("executors before run: {}", svc.live_executors());

    let t0 = std::time::Instant::now();
    let report = stack.engine.run(&prog)?;
    let dt = t0.elapsed().as_secs_f64();

    let stats = svc.stats();
    let peak =
        stats.peak_executors.load(std::sync::atomic::Ordering::SeqCst);
    let busy_s =
        stats.busy_us.load(std::sync::atomic::Ordering::SeqCst) as f64 / 1e6;
    println!(
        "\nexecuted {} tasks in {dt:.2}s; DRP peak executors {peak}; {:.2}s CPU consumed",
        report.executed, busy_s
    );
    println!(
        "speedup {:.1}x on up to {peak} executors (efficiency {:.0}%)",
        busy_s / dt,
        100.0 * busy_s / (dt * peak.max(1) as f64)
    );
    for (stage, recs) in report.timeline.by_stage() {
        println!("  {stage:<14} x{}", recs.len());
    }
    if report.executed as usize != expected {
        bail!("expected {expected} tasks, executed {}", report.executed);
    }
    // DRP shrink: after the run, idle executors deregister.
    std::thread::sleep(std::time::Duration::from_millis(800));
    println!("executors after idle timeout: {}", svc.live_executors());
    println!("moldyn_study OK");
    Ok(())
}
