//! Quickstart: compile and run a small SwiftScript program on the local
//! provider, showing the core pieces — dataset typing, an atomic
//! procedure, foreach parallelism, and the run report.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use gridswift::stack::{build, ProviderKind, StackOptions};
use gridswift::swiftscript::compile;

fn main() -> Result<()> {
    let wd = std::env::temp_dir().join("gridswift_quickstart");
    let _ = std::fs::remove_dir_all(&wd);
    std::fs::create_dir_all(&wd)?;

    // A tiny input dataset: four numbered files.
    for i in 0..4 {
        std::fs::write(wd.join(format!("sample_{i}.dat")), format!("data {i}"))?;
    }

    // SwiftScript: map the files, apply a (sleep) analysis to each in
    // parallel, chain a second stage.
    let src = format!(
        r#"
type Sample {{}};
(Sample o) analyze (Sample i) {{
  app {{ sleep_ms 50 @filename(i) @filename(o); }}
}}
(Sample o) summarize (Sample i) {{
  app {{ sleep_ms 20 @filename(i) @filename(o); }}
}}
Sample samples[]<array_mapper;location="{dir}",prefix="sample_",suffix=".dat">;
Sample analyzed[];
foreach s, i in samples {{
  analyzed[i] = analyze(s);
}}
Sample summaries[];
foreach a, i in analyzed {{
  summaries[i] = summarize(a);
}}
"#,
        dir = wd.display()
    );

    println!("== gridswift quickstart ==");
    let prog = compile(&src)?;
    println!(
        "compiled: {} types, {} procedures, {} statements",
        3, // Sample + 2 implicit? just informational
        prog.procs.len(),
        prog.globals.len()
    );

    let stack = build(StackOptions {
        provider: ProviderKind::Local,
        workers: 4,
        workdir: wd.clone(),
        provenance: true,
        ..Default::default()
    })?;
    let t0 = std::time::Instant::now();
    let report = stack.engine.run(&prog)?;
    let dt = t0.elapsed();

    println!(
        "executed {} tasks in {:.0} ms (8 x 50/20 ms of work on 4 workers)",
        report.executed,
        dt.as_secs_f64() * 1e3
    );
    for (stage, start, end) in report.timeline.stage_windows() {
        println!("  stage {stage:<10} {start:>6.3}s .. {end:>6.3}s");
    }
    if let Some(vdc) = &stack.vdc {
        println!("provenance: {} invocation records captured", vdc.len());
    }
    assert_eq!(report.executed, 8);
    println!("quickstart OK");
    Ok(())
}
