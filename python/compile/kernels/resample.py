"""Affine resampling kernels: fMRI ``reslice`` and Montage ``mProjectPP``.

Both the fMRI reslice step (apply the affine estimated by ``alignlinear``)
and the Montage plate reprojection (map a plate into the common mosaic
coordinate frame) are, on the paper's CPU testbed, per-pixel interpolation
loops. The TPU adaptation (DESIGN.md §Hardware-Adaptation): a separable
affine resample is a chain of dense contractions with 1-D interpolation-
weight matrices, so the whole operation becomes two/three tiled MXU matmuls
(see ``common.resample_matrix``) instead of an irregular gather:

    image' = W_rows @ image @ W_cols^T
    vol'   = resample each axis in turn via a (flattened) matmul

The matmuls run through the shared accumulating Pallas tile kernel.
"""

import functools

import jax
import jax.numpy as jnp

from .common import matmul, resample_matrix


@functools.partial(jax.jit, static_argnames=())
def mproject(img, params):
    """Reproject a 2-D plate by the separable affine ``params``.

    ``params`` = [scale_r, shift_r, scale_c, shift_c] (f32[4]): output pixel
    (i, j) samples input at (i*scale_r + shift_r, j*scale_c + shift_c),
    bilinearly. Out-of-plate samples are zero (the mosaic engine later
    weights them out via the coverage map).
    """
    h, w = img.shape
    wr = resample_matrix(h, h, params[0], params[1])
    wc = resample_matrix(w, w, params[2], params[3])
    tmp = matmul(wr, img)  # rows
    return matmul(tmp, wc.T)  # cols


@functools.partial(jax.jit, static_argnames=())
def reslice(vol, params):
    """Apply a separable affine to a volume (X, Y, Z).

    ``params`` = [sx, tx, sy, ty, sz, tz]: per-axis scale+shift, the
    separable core of the paper's 12-parameter AIR model (rotations are
    handled upstream by ``reorient``'s axis flips/permutes in this
    reproduction). Each axis is resampled by flattening the other two axes
    and contracting with the axis' weight matrix on the MXU.
    """
    x, y, z = vol.shape
    wx = resample_matrix(x, x, params[0], params[1])
    wy = resample_matrix(y, y, params[2], params[3])
    wz = resample_matrix(z, z, params[4], params[5])
    # axis 0: (X,Y,Z) -> X x (Y*Z)
    v = matmul(wx, vol.reshape(x, y * z)).reshape(x, y, z)
    # axis 1: bring Y forward
    v = jnp.transpose(v, (1, 0, 2)).reshape(y, x * z)
    v = matmul(wy, v).reshape(y, x, z).transpose(1, 0, 2)
    # axis 2: bring Z forward
    v = jnp.transpose(v, (2, 0, 1)).reshape(z, x * y)
    v = matmul(wz, v).reshape(z, x, y).transpose(1, 2, 0)
    return v
