"""Montage ``mAdd`` kernel: weighted co-addition of background-corrected plates.

Paper §3.6 image co-addition: co-add K corrected plates (optionally per
sub-region) into a mosaic. The kernel streams one (k, row-slab) block per
grid step — the K axis is the innermost grid dimension so each output slab
stays VMEM-resident across the whole accumulation, exactly the schedule a
TPU would use to stream K plates from HBM through a single VMEM tile.

Each plate carries a scalar weight (its overlap-coverage weight); the
normalization by total weight is a trailing elementwise step fused by XLA.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block


def _coadd_kernel(stack_ref, w_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += stack_ref[0] * w_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("br",))
def coadd(stack, weights, *, br: int = 64):
    """Weighted mean of ``stack`` f32[K,H,W] with ``weights`` f32[K]."""
    k, h, w = stack.shape
    br = pick_block(h, br)
    wsum = jnp.sum(weights)
    w2d = weights.reshape(k, 1)
    acc = pl.pallas_call(
        _coadd_kernel,
        grid=(h // br, k),
        in_specs=[
            pl.BlockSpec((1, br, w), lambda i, kk: (kk, i, 0)),
            pl.BlockSpec((1, 1), lambda i, kk: (kk, 0)),
        ],
        out_specs=pl.BlockSpec((br, w), lambda i, kk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=INTERPRET,
    )(stack, w2d)
    return acc / jnp.maximum(wsum, 1e-12)
