"""fMRI ``reorient`` kernel: flip a brain volume along one axis.

Paper §3.3: the atomic procedure ``reorient`` rotates a brain image along a
given axis; it is the fan-out stage of the fMRI workflow (one call per
volume, hundreds per run). The kernel is a pure memory-layout operation —
the interesting part is the BlockSpec: the output block at slab index ``i``
reads the *mirrored* input slab, so the HBM<->VMEM schedule does the global
reversal while the kernel body reverses within the block. Nothing is ever
resident beyond one (X, Y, bz) slab per step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block


def _flip0_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...][::-1, :, :]


def _flip1_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...][:, ::-1, :]


def _flip2_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...][:, :, ::-1]


@functools.partial(jax.jit, static_argnames=("axis", "bz"))
def reorient(vol, *, axis: int = 1, bz: int = 8):
    """Flip ``vol`` (X, Y, Z) along ``axis`` (0=x, 1=y, 2=z)."""
    x, y, z = vol.shape
    bz = pick_block(z, bz)
    nz = z // bz
    kernel = (_flip0_kernel, _flip1_kernel, _flip2_kernel)[axis]
    if axis == 2:
        # Mirrored slab schedule: output slab i <- input slab nz-1-i.
        in_map = lambda i: (0, 0, nz - 1 - i)
    else:
        in_map = lambda i: (0, 0, i)
    return pl.pallas_call(
        kernel,
        grid=(nz,),
        in_specs=[pl.BlockSpec((x, y, bz), in_map)],
        out_specs=pl.BlockSpec((x, y, bz), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((x, y, z), vol.dtype),
        interpret=INTERPRET,
    )(vol)
