"""Shared Pallas helpers: the tiled-matmul primitive and tiling utilities.

All kernels in this package are authored for TPU structure (VMEM block
tiling via BlockSpec, MXU-shaped contractions) but are lowered with
``interpret=True``: the CPU PJRT plugin cannot execute Mosaic custom-calls,
so interpret mode is the correctness path and TPU efficiency is estimated
from the BlockSpec footprint (see DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU-PJRT correctness path; see module docstring.


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= preferred (>=1)."""
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return b


def _matmul_kernel(x_ref, y_ref, o_ref):
    """Accumulating matmul tile: o[i,j] += x[i,k] @ y[k,j] over grid dim 2."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(x, y, *, bm: int = 64, bk: int = 64, bn: int = 64):
    """Tiled Pallas matmul ``x @ y`` for f32 operands.

    The grid iterates (M/bm, N/bn, K/bk) with the K axis innermost so the
    output block stays resident in VMEM across the contraction — the
    canonical MXU pipelining schedule.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {y.shape}"
    bm = pick_block(m, bm)
    bk = pick_block(k, bk)
    bn = pick_block(n, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x, y)


def resample_matrix(n_out: int, n_in: int, scale: float, shift: float):
    """Dense 1-D linear-interpolation resampling matrix W (n_out x n_in).

    Row i holds the two bilinear weights for source coordinate
    ``src = i * scale + shift``; out-of-range rows are zero. Expressing
    gather-style resampling as a dense matmul is the TPU adaptation of the
    paper's CPU-era per-pixel interpolation loops: the irregular gather
    becomes an MXU contraction (see DESIGN.md §Hardware-Adaptation).
    """
    i = jnp.arange(n_out, dtype=jnp.float32)
    src = i * scale + shift
    lo = jnp.floor(src)
    frac = src - lo
    lo_i = lo.astype(jnp.int32)
    cols = jnp.arange(n_in, dtype=jnp.int32)
    lo_w = jnp.where((lo_i >= 0) & (lo_i < n_in), 1.0 - frac, 0.0)
    hi_w = jnp.where((lo_i + 1 >= 0) & (lo_i + 1 < n_in), frac, 0.0)
    w = (cols[None, :] == lo_i[:, None]) * lo_w[:, None] + (
        cols[None, :] == (lo_i + 1)[:, None]
    ) * hi_w[:, None]
    return w.astype(jnp.float32)
