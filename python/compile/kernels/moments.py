"""``alignlinear`` moment-accumulation kernel.

Paper §3.3: ``alignlinear`` estimates the (12-parameter AIR-style) spatial
adjustment between a volume and a reference. We reproduce it as intensity-
weighted moment matching: this kernel computes the 10 weighted moments

    [ Sw, Swx, Swy, Swz, Swxx, Swyy, Swzz, Swxy, Swxz, Swyz ]

of a volume, tiled over Z slabs, accumulating partial sums in a VMEM-
resident (1, 16) output block (padded to the 16-lane register width). The
surrounding L2 model (model.alignlinear_params) solves the tiny 4x4 system
from the moments of both volumes to produce the affine parameters — the
classic partial-reduction-in-kernel / solve-outside split.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block

NMOM = 10
_PAD = 16  # lane-width padding for the accumulator block


def _moments_kernel(x_ref, o_ref, *, bz: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = x_ref[...]
    x, y, z = w.shape
    z0 = (pl.program_id(0) * bz).astype(jnp.float32)
    xi = jax.lax.broadcasted_iota(jnp.float32, (x, y, z), 0)
    yi = jax.lax.broadcasted_iota(jnp.float32, (x, y, z), 1)
    zi = jax.lax.broadcasted_iota(jnp.float32, (x, y, z), 2) + z0
    mom = jnp.stack(
        [
            jnp.sum(w),
            jnp.sum(w * xi),
            jnp.sum(w * yi),
            jnp.sum(w * zi),
            jnp.sum(w * xi * xi),
            jnp.sum(w * yi * yi),
            jnp.sum(w * zi * zi),
            jnp.sum(w * xi * yi),
            jnp.sum(w * xi * zi),
            jnp.sum(w * yi * zi),
        ]
    )
    o_ref[...] += jnp.pad(mom, (0, _PAD - NMOM))[None, :]


@functools.partial(jax.jit, static_argnames=("bz",))
def moments(vol, *, bz: int = 8):
    """Weighted spatial moments of ``vol`` (X, Y, Z) -> (NMOM,) f32."""
    x, y, z = vol.shape
    bz = pick_block(z, bz)
    out = pl.pallas_call(
        functools.partial(_moments_kernel, bz=bz),
        grid=(z // bz,),
        in_specs=[pl.BlockSpec((x, y, bz), lambda i: (0, 0, i))],
        out_specs=pl.BlockSpec((1, _PAD), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, _PAD), jnp.float32),
        interpret=INTERPRET,
    )(vol)
    return out[0, :NMOM]
