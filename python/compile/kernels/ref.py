"""Pure-jnp oracles for every Pallas kernel.

Each function here is the straightforward (un-tiled, un-scheduled) jnp
formulation of the corresponding kernel; pytest + hypothesis assert
``allclose`` across shape/dtype sweeps. These are the CORE correctness
signal for Layer 1 (see python/tests/test_kernels.py).
"""

import jax.numpy as jnp

from .common import resample_matrix
from .mdenergy import EPS, RCUT2, SIGMA


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def reorient_ref(vol, axis: int):
    return jnp.flip(vol, axis=axis)


def moments_ref(vol):
    x, y, z = vol.shape
    xi, yi, zi = jnp.meshgrid(
        jnp.arange(x, dtype=jnp.float32),
        jnp.arange(y, dtype=jnp.float32),
        jnp.arange(z, dtype=jnp.float32),
        indexing="ij",
    )
    w = vol
    return jnp.stack(
        [
            jnp.sum(w),
            jnp.sum(w * xi),
            jnp.sum(w * yi),
            jnp.sum(w * zi),
            jnp.sum(w * xi * xi),
            jnp.sum(w * yi * yi),
            jnp.sum(w * zi * zi),
            jnp.sum(w * xi * yi),
            jnp.sum(w * xi * zi),
            jnp.sum(w * yi * zi),
        ]
    )


def mproject_ref(img, params):
    h, w = img.shape
    wr = resample_matrix(h, h, params[0], params[1])
    wc = resample_matrix(w, w, params[2], params[3])
    return wr @ img @ wc.T


def reslice_ref(vol, params):
    x, y, z = vol.shape
    wx = resample_matrix(x, x, params[0], params[1])
    wy = resample_matrix(y, y, params[2], params[3])
    wz = resample_matrix(z, z, params[4], params[5])
    return jnp.einsum("ai,bj,ck,ijk->abc", wx, wy, wz, vol)


def difffit_ref(a, b):
    d = a - b
    h, w = d.shape
    ri, ci = jnp.meshgrid(
        jnp.arange(h, dtype=jnp.float32),
        jnp.arange(w, dtype=jnp.float32),
        indexing="ij",
    )
    sums = jnp.stack(
        [jnp.sum(d), jnp.sum(d * ri), jnp.sum(d * ci), jnp.sum(d * d)]
    )
    return d, sums


def coadd_ref(stack, weights):
    num = jnp.einsum("k,khw->hw", weights, stack)
    return num / jnp.maximum(jnp.sum(weights), 1e-12)


def mdenergy_ref(pos):
    n = pos.shape[0]
    diff = pos[:, None, :] - pos[None, :, :]  # (n, n, 3)
    r2 = jnp.sum(diff * diff, axis=-1)
    mask = ~jnp.eye(n, dtype=bool)
    r2s = jnp.where(mask, r2, 1.0)
    inv2 = SIGMA * SIGMA / r2s
    inv6 = inv2 * inv2 * inv2
    e = 4.0 * EPS * (inv6 * inv6 - inv6)
    fac = 24.0 * EPS * (2.0 * inv6 * inv6 - inv6) / r2s
    keep = mask & (r2 < RCUT2)
    e = jnp.where(keep, e, 0.0)
    fac = jnp.where(keep, fac, 0.0)
    forces = jnp.sum(fac[:, :, None] * diff, axis=1)
    return forces, 0.5 * jnp.sum(e)


def wham_iterate_ref(counts, bias, nsamp, f):
    denom = jnp.sum(nsamp * jnp.exp(f - bias), axis=0, keepdims=True)
    p = counts / jnp.maximum(denom, 1e-30)
    fout = -jnp.log(
        jnp.maximum(jnp.sum(p * jnp.exp(-bias), axis=1, keepdims=True), 1e-30)
    )
    return fout - fout[0:1, :], p
