"""MolDyn energy/force kernel: tiled Lennard-Jones with MXU distance trick.

Paper §5.4.3: each MolDyn job runs CHARMM-style molecular mechanics
(equilibration, free-energy perturbation). The numeric core is the pairwise
nonbonded loop. TPU adaptation: the O(N^2) distance computation is
restructured so its dominant term is a matmul —

    |r_i - r_j|^2 = |r_i|^2 + |r_j|^2 - 2 r_i . r_j

where ``r_i . r_j`` is pos @ pos^T, an MXU contraction. The kernel tiles
rows of the force matrix: each grid step owns a (BR, 3) row block, loads
the full (N, 3) position table (N<=128 fits VMEM trivially), and reduces
its row slab of LJ forces and energy. Self-interaction is masked by index.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block

_PAD = 16
EPS = 1.0  # LJ well depth (reduced units)
SIGMA = 1.0  # LJ diameter (reduced units)
RCUT2 = 9.0  # squared cutoff (3 sigma)


def _lj_terms(r2, mask):
    """Pairwise LJ energy and dU/dr * 1/r factors, masked."""
    r2s = jnp.where(mask, r2, 1.0)  # keep rsqrt finite off-pairs
    inv2 = SIGMA * SIGMA / r2s
    inv6 = inv2 * inv2 * inv2
    e = 4.0 * EPS * (inv6 * inv6 - inv6)
    # f(r)/r such that F_i = sum_j fac * (r_i - r_j)
    fac = 24.0 * EPS * (2.0 * inv6 * inv6 - inv6) / r2s
    keep = mask & (r2 < RCUT2)
    return jnp.where(keep, e, 0.0), jnp.where(keep, fac, 0.0)


def _mdenergy_kernel(rows_ref, all_ref, f_ref, e_ref, *, br: int):
    i0 = pl.program_id(0) * br
    rows = rows_ref[...]  # (br, 3)
    allp = all_ref[...]  # (n, 3)
    n = allp.shape[0]
    # MXU term: rows @ allp^T
    dots = jnp.dot(rows, allp.T, preferred_element_type=jnp.float32)
    rn = jnp.sum(rows * rows, axis=1, keepdims=True)
    an = jnp.sum(allp * allp, axis=1, keepdims=True)
    r2 = rn + an.T - 2.0 * dots  # (br, n)
    ii = jax.lax.broadcasted_iota(jnp.int32, (br, n), 0) + i0
    jj = jax.lax.broadcasted_iota(jnp.int32, (br, n), 1)
    mask = ii != jj
    e, fac = _lj_terms(r2, mask)
    # F_i = sum_j fac_ij * (r_i - r_j)
    fx = jnp.sum(fac, axis=1, keepdims=True) * rows - jnp.dot(
        fac, allp, preferred_element_type=jnp.float32
    )
    f_ref[...] = fx
    e_ref[...] = jnp.full_like(e_ref, 0.5 * jnp.sum(e))


@functools.partial(jax.jit, static_argnames=("br",))
def mdenergy(pos, *, br: int = 32):
    """LJ energy and forces for ``pos`` f32[N,3].

    Returns ``(forces f32[N,3], energy f32[])``. Energy halves the double-
    counted pair sum.
    """
    n = pos.shape[0]
    br = pick_block(n, br)
    grid = (n // br,)
    forces, eparts = pl.pallas_call(
        functools.partial(_mdenergy_kernel, br=br),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, 3), lambda i: (i, 0)),
            pl.BlockSpec((n, 3), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, 3), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 3), jnp.float32),
            jax.ShapeDtypeStruct((grid[0], 1), jnp.float32),
        ],
        interpret=INTERPRET,
    )(pos, pos)
    return forces, jnp.sum(eparts)
