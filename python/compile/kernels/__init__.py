"""Layer-1 Pallas kernels for the gridswift reproduction.

One module per compute hot-spot of the paper's three evaluation
applications (fMRI, Montage, MolDyn); ``ref`` holds the pure-jnp oracles.
"""

from .coadd import coadd
from .common import matmul, resample_matrix
from .difffit import difffit
from .mdenergy import mdenergy
from .moments import moments
from .reorient import reorient
from .resample import mproject, reslice
from .wham import wham_iterate

__all__ = [
    "coadd",
    "difffit",
    "matmul",
    "mdenergy",
    "moments",
    "mproject",
    "reorient",
    "resample_matrix",
    "reslice",
    "wham_iterate",
]
