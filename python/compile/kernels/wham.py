"""WHAM iteration kernel (MolDyn stage 6).

Paper §5.4.3 stage 6: the weighted-histogram analysis method combines the
biased histograms from the three coupling stages into free energies. One
WHAM self-consistency iteration:

    denom_b = sum_s n_s * exp(f_s - u_{s,b})
    p_b     = c_b / denom_b
    f'_s    = -log( sum_b p_b * exp(-u_{s,b}) )

with S states x B bins. The kernel keeps the whole (S, B) bias table in one
VMEM block (S, B are tiny) and does the two contractions back to back; the
exponentials are VPU work between the two MXU-shaped reductions.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET


def _wham_kernel(counts_ref, bias_ref, nsamp_ref, f_ref, fout_ref, p_ref):
    c = counts_ref[...]  # (1, B) total counts per bin
    u = bias_ref[...]  # (S, B) bias energies
    n = nsamp_ref[...]  # (S, 1) samples per state
    f = f_ref[...]  # (S, 1) current free energies
    denom = jnp.sum(n * jnp.exp(f - u), axis=0, keepdims=True)  # (1, B)
    p = c / jnp.maximum(denom, 1e-30)
    fout = -jnp.log(
        jnp.maximum(jnp.sum(p * jnp.exp(-u), axis=1, keepdims=True), 1e-30)
    )
    p_ref[...] = p
    fout_ref[...] = fout


@jax.jit
def wham_iterate(counts, bias, nsamp, f):
    """One WHAM iteration.

    counts f32[1,B], bias f32[S,B], nsamp f32[S,1], f f32[S,1]
    -> (f' f32[S,1], p f32[1,B])
    """
    s, b = bias.shape
    fout, p = pl.pallas_call(
        _wham_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((s, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, b), jnp.float32),
        ],
        interpret=INTERPRET,
    )(counts, bias, nsamp, f)
    # Gauge fix: anchor state 0 at zero free energy.
    return fout - fout[0:1, :], p
