"""Montage ``mDiffFit`` kernel: image difference + plane-fit partials.

Paper §3.6: in the background-rectification stage Montage computes the
difference of every overlapping plate pair and fits a plane to each
difference image. This kernel fuses the two: tiled over row slabs, it emits
the difference image and accumulates the plane-fit normal-equation partials

    [ Sd, Sd*x, Sd*y, Sd^2 ]          (x=row coord, y=col coord)

in a VMEM-resident accumulator (the static design-matrix sums S1, Sx, Sy,
Sxx, ... depend only on the image shape and are computed closed-form in the
L2 model, which solves the 3x3 system for the plane coefficients).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block

NSUM = 4
_PAD = 16


def _difffit_kernel(a_ref, b_ref, d_ref, s_ref, *, br: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    d = a_ref[...] - b_ref[...]
    d_ref[...] = d
    h, w = d.shape
    r0 = (pl.program_id(0) * br).astype(jnp.float32)
    ri = jax.lax.broadcasted_iota(jnp.float32, (h, w), 0) + r0
    ci = jax.lax.broadcasted_iota(jnp.float32, (h, w), 1)
    sums = jnp.stack(
        [jnp.sum(d), jnp.sum(d * ri), jnp.sum(d * ci), jnp.sum(d * d)]
    )
    s_ref[...] += jnp.pad(sums, (0, _PAD - NSUM))[None, :]


@functools.partial(jax.jit, static_argnames=("br",))
def difffit(a, b, *, br: int = 64):
    """Difference image and plane-fit partial sums of two plates.

    Returns ``(diff f32[H,W], sums f32[NSUM])``.
    """
    h, w = a.shape
    br = pick_block(h, br)
    diff, sums = pl.pallas_call(
        functools.partial(_difffit_kernel, br=br),
        grid=(h // br,),
        in_specs=[
            pl.BlockSpec((br, w), lambda i: (i, 0)),
            pl.BlockSpec((br, w), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, w), lambda i: (i, 0)),
            pl.BlockSpec((1, _PAD), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, w), jnp.float32),
            jax.ShapeDtypeStruct((1, _PAD), jnp.float32),
        ],
        interpret=INTERPRET,
    )(a, b)
    return diff, sums[0, :NSUM]
