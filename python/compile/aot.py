"""AOT lowering: JAX/Pallas models -> HLO text artifacts for the Rust runtime.

Interchange format is HLO **text**, NOT serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Every artifact is lowered with ``return_tuple=True``; the Rust side unwraps
with ``to_tuple()``. A ``manifest.txt`` describing names, input and output
shapes is emitted next to the artifacts so the Rust ArtifactRegistry can
validate literals without parsing HLO.

Usage:
    python -m compile.aot --out-dir ../artifacts [--only NAME]
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import ARTIFACTS


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _fmt_shape(s) -> str:
    dims = ",".join(str(d) for d in s.shape)
    return f"f32[{dims}]"


def lower_artifact(name: str, out_dir: str) -> str:
    """Lower one artifact; returns its manifest line."""
    fn, specs = ARTIFACTS[name]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    # Evaluate output shapes from the jax signature (abstract eval).
    out_shapes = jax.eval_shape(fn, *specs)
    ins = ";".join(_fmt_shape(s) for s in specs)
    outs = ";".join(_fmt_shape(s) for s in out_shapes)
    print(f"  {name}: {len(text)} chars, in=[{ins}] out=[{outs}]")
    return f"{name} inputs={ins} outputs={outs}"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single artifact")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = [args.only] if args.only else sorted(ARTIFACTS)
    lines = []
    for name in names:
        lines.append(lower_artifact(name, args.out_dir))
    manifest = os.path.join(args.out_dir, "manifest.txt")
    if args.only:
        # Merge into an existing manifest if present.
        old = {}
        if os.path.exists(manifest):
            with open(manifest) as f:
                for ln in f:
                    if ln.strip():
                        old[ln.split()[0]] = ln.strip()
        for ln in lines:
            old[ln.split()[0]] = ln
        lines = [old[k] for k in sorted(old)]
    with open(manifest, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {len(lines)} artifact(s) + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
