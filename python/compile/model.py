"""Layer-2 JAX models: the per-application compute graphs.

Each public function here is one AOT artifact: it composes the Layer-1
Pallas kernels with the surrounding (XLA-fused) glue math, is lowered once
by ``aot.py`` to HLO text, and is executed from the Rust coordinator via
PJRT. Nothing in this module runs on the request path.

Applications (paper §5.4):
- fMRI spatial normalization: reorient (axis flips), alignlinear (moment
  matching -> separable affine), reslice (apply affine).
- Montage: mProjectPP (plate reprojection), mDiffFit (difference + plane
  fit), background correction, mAdd (co-addition).
- MolDyn: CHARMM-style equilibration (steepest descent on the LJ surface),
  single-point energy, WHAM free-energy solve.
"""

import functools

import jax
import jax.numpy as jnp

from . import shapes
from .kernels import (
    coadd,
    difffit,
    mdenergy,
    moments,
    mproject,
    reorient,
    reslice,
    wham_iterate,
)

# --------------------------------------------------------------------------
# fMRI
# --------------------------------------------------------------------------


def fmri_reorient_x(vol):
    """Atomic procedure ``reorient(v, "x")``: flip along the X axis."""
    return (reorient(vol, axis=0),)


def fmri_reorient_y(vol):
    """Atomic procedure ``reorient(v, "y")``: flip along the Y axis."""
    return (reorient(vol, axis=1),)


def fmri_reorient_z(vol):
    """Atomic procedure ``reorient(v, "z")``: flip along the Z axis."""
    return (reorient(vol, axis=2),)


def _axis_stats(mom):
    """Per-axis (mean, var) from the 10-moment vector."""
    sw = jnp.maximum(mom[0], 1e-12)
    means = mom[1:4] / sw
    vars_ = mom[4:7] / sw - means * means
    return means, jnp.maximum(vars_, 1e-12)


def fmri_alignlinear(vol, ref_vol):
    """``alignlinear``: separable affine parameters matching vol -> ref.

    Output params [sx, tx, sy, ty, sz, tz] such that resampling ``vol`` at
    ``src_axis = i * s + t`` matches the reference's intensity-weighted
    spatial moments (the moment-matching core of AIR's 12-parameter model;
    rotations are handled by the reorient stages).
    """
    mv, vv = _axis_stats(moments(vol))
    mr, vr = _axis_stats(moments(ref_vol))
    s = jnp.sqrt(vv / vr)
    t = mv - s * mr
    params = jnp.stack([s[0], t[0], s[1], t[1], s[2], t[2]])
    return (params,)


def fmri_reslice(vol, params):
    """``reslice``: apply the separable affine estimated by alignlinear."""
    return (reslice(vol, params),)


def fmri_volume_chain(vol, ref_vol):
    """Fused single-volume pipeline: reorient_y . reorient_x . align . reslice.

    Used by the Swift ``clustering`` optimization when all four stages of
    one volume land in the same bundle — XLA fuses the whole chain so the
    intermediate volumes never round-trip through host memory.
    """
    v = reorient(vol, axis=1)
    v = reorient(v, axis=0)
    r = reorient(ref_vol, axis=1)
    r = reorient(r, axis=0)
    (params,) = fmri_alignlinear(v, r)
    return (reslice(v, params), params)


# --------------------------------------------------------------------------
# Montage
# --------------------------------------------------------------------------


def montage_project(img, params):
    """``mProjectPP``: reproject a plate into the mosaic frame."""
    return (mproject(img, params),)


def _plane_static_sums(h: int, w: int):
    """Closed-form design-matrix sums for the plane fit over an HxW grid."""
    n = float(h * w)
    sx = w * (h - 1) * h / 2.0
    sy = h * (w - 1) * w / 2.0
    sxx = w * (h - 1) * h * (2 * h - 1) / 6.0
    syy = h * (w - 1) * w * (2 * w - 1) / 6.0
    sxy = ((h - 1) * h / 2.0) * ((w - 1) * w / 2.0)
    return jnp.array(
        [[n, sx, sy], [sx, sxx, sxy], [sy, sxy, syy]], jnp.float32
    )


def montage_difffit(a, b):
    """``mDiffFit``: difference image + fitted plane coefficients.

    Returns (diff, coeffs[3]) with plane p(x, y) = c0 + c1*x + c2*y fitted
    to ``a - b`` by least squares. Over a full HxW grid the normal
    equations diagonalize exactly when coordinates are centered at the
    grid centroid (sum(x - xbar) = 0, sum((x-xbar)(y-ybar)) = 0), so the
    fit is three stable f32 divisions — no LAPACK solve, which matters
    because ``jnp.linalg.solve`` lowers to a typed-FFI custom-call that
    xla_extension 0.5.1 (the Rust runtime's XLA) cannot execute.
    """
    d, sums = difffit(a, b)
    h, w = a.shape
    n = float(h * w)
    xbar = (h - 1) / 2.0
    ybar = (w - 1) / 2.0
    # Centered second moments of a full grid (closed form).
    sxx_c = n * (h * h - 1) / 12.0
    syy_c = n * (w * w - 1) / 12.0
    sd, sdx, sdy = sums[0], sums[1], sums[2]
    c1 = (sdx - xbar * sd) / sxx_c
    c2 = (sdy - ybar * sd) / syy_c
    c0 = sd / n - c1 * xbar - c2 * ybar
    coeffs = jnp.stack([c0, c1, c2])
    return (d, coeffs)


def montage_bgcorrect(img, coeffs):
    """``mBackground``: subtract the fitted plane from a plate."""
    h, w = img.shape
    ri = jnp.arange(h, dtype=jnp.float32)[:, None]
    ci = jnp.arange(w, dtype=jnp.float32)[None, :]
    plane = coeffs[0] + coeffs[1] * ri + coeffs[2] * ci
    return (img - plane,)


def montage_coadd(stack, weights):
    """``mAdd``: weighted co-addition of K corrected plates."""
    return (coadd(stack, weights),)


# --------------------------------------------------------------------------
# MolDyn
# --------------------------------------------------------------------------

EQUIL_STEPS = 20
EQUIL_LR = 1e-3
EQUIL_FMAX = 50.0  # force clamp: steepest descent stability


def moldyn_energy(pos):
    """Single-point LJ energy + forces (CHARMM energy call analogue)."""
    f, e = mdenergy(pos)
    return (f, e.reshape(1))


def moldyn_equilibrate(pos):
    """``CHARMM equilibration``: EQUIL_STEPS of clamped steepest descent.

    The loop stays inside one executable (lax.fori_loop) so a single PJRT
    dispatch performs the whole equilibration — the Rust side treats it as
    one task, exactly like the paper's per-molecule CHARMM job.
    """

    def body(_, carry):
        p, _e = carry
        f, e = mdenergy(p)
        f = jnp.clip(f, -EQUIL_FMAX, EQUIL_FMAX)
        return (p + EQUIL_LR * f, e)

    pos_out, e = jax.lax.fori_loop(
        0, EQUIL_STEPS, body, (pos, jnp.float32(0.0))
    )
    return (pos_out, e.reshape(1))


WHAM_ITERS = 50


def moldyn_wham(counts, bias, nsamp):
    """WHAM free-energy solve: WHAM_ITERS fixed-point iterations."""

    def body(_, carry):
        f, _p = carry
        return wham_iterate(counts, bias, nsamp, f)

    f0 = jnp.zeros((bias.shape[0], 1), jnp.float32)
    p0 = jnp.zeros_like(counts)
    f, p = jax.lax.fori_loop(0, WHAM_ITERS, body, (f0, p0))
    return (f, p)


# --------------------------------------------------------------------------
# Artifact registry: name -> (fn, input ShapeDtypeStructs)
# --------------------------------------------------------------------------


def _f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


VOL = shapes.VOLUME
IMG = shapes.IMAGE
IMG_S = shapes.IMAGE_SMALL


ARTIFACTS = {
    "reorient_x": (fmri_reorient_x, [_f32(VOL)]),
    "reorient_y": (fmri_reorient_y, [_f32(VOL)]),
    "reorient_z": (fmri_reorient_z, [_f32(VOL)]),
    "alignlinear": (fmri_alignlinear, [_f32(VOL), _f32(VOL)]),
    "reslice": (fmri_reslice, [_f32(VOL), _f32((6,))]),
    "fmri_chain": (fmri_volume_chain, [_f32(VOL), _f32(VOL)]),
    "mproject": (montage_project, [_f32(IMG), _f32((4,))]),
    "mproject_small": (montage_project, [_f32(IMG_S), _f32((4,))]),
    "mdifffit": (montage_difffit, [_f32(IMG), _f32(IMG)]),
    "mdifffit_small": (montage_difffit, [_f32(IMG_S), _f32(IMG_S)]),
    "mbgcorrect": (montage_bgcorrect, [_f32(IMG), _f32((3,))]),
    "madd": (
        montage_coadd,
        [_f32((shapes.COADD_K,) + IMG), _f32((shapes.COADD_K,))],
    ),
    "madd_small": (
        montage_coadd,
        [_f32((shapes.COADD_K,) + IMG_S), _f32((shapes.COADD_K,))],
    ),
    "mdenergy": (moldyn_energy, [_f32((shapes.ATOMS, 3))]),
    "mdequil": (moldyn_equilibrate, [_f32((shapes.ATOMS, 3))]),
    "wham": (
        moldyn_wham,
        [
            _f32((1, shapes.WHAM_BINS)),
            _f32((shapes.WHAM_STATES, shapes.WHAM_BINS)),
            _f32((shapes.WHAM_STATES, 1)),
        ],
    ),
}
