"""Canonical shapes for AOT-compiled artifacts.

The Rust runtime loads fixed-shape HLO executables; these constants define
the shapes baked into every artifact (and mirrored in rust/src/runtime/
artifact metadata). They follow the paper's workload scales:

- fMRI volume: the paper's volumes are ~200 KB image + small header. A
  64x64x24 f32 voxel grid is 384 KiB raw / ~196 KB in the int16 on-disk
  encoding the scanner uses; we keep f32 compute at 64x64x24.
- Montage image: paper images are ~2 MB FITS; 512x512 f32 plates twinned
  with a 256x256 "fast preview" shape used in tests.
- MolDyn: ligands of up to 128 atoms (the NIST neutral-ligand library is
  small molecules), CHARMM-style energy over 128-atom frames.
- WHAM: 8 coupling states x 64 histogram bins (three coupling stages in
  the paper; we keep a power-of-two padding for clean VMEM tiling).
"""

# fMRI
VOLUME = (64, 64, 24)  # (X, Y, Z) voxels, f32

# Montage
IMAGE = (512, 512)  # full-size plate
IMAGE_SMALL = (256, 256)  # test/preview plate
COADD_K = 8  # images co-added per madd invocation

# MolDyn
ATOMS = 128  # atoms per ligand frame (padded)
MD_ROW_BLOCK = 32  # row tile for the pairwise-energy kernel

# WHAM
WHAM_STATES = 8
WHAM_BINS = 64

# Pallas tiling defaults (TPU-friendly: multiples of (8, 128) where the
# trailing dims allow; on the 64-wide fMRI volumes we fall back to the
# largest divisor).
MATMUL_BLOCK = (64, 64, 64)  # (bm, bk, bn)
