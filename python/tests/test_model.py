"""Layer-2 semantic tests: the per-application model graphs do what the
paper's programs do (shape contracts + domain invariants)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import shapes


def _vol(rng):
    return jnp.asarray(
        np.abs(rng.normal(size=shapes.VOLUME)).astype(np.float32)
    )


def _gaussian_vol(center, sigma=6.0):
    x, y, z = shapes.VOLUME
    xi, yi, zi = np.meshgrid(
        np.arange(x), np.arange(y), np.arange(z), indexing="ij"
    )
    r2 = (
        (xi - center[0]) ** 2 + (yi - center[1]) ** 2 + (zi - center[2]) ** 2
    )
    return jnp.asarray(np.exp(-r2 / (2 * sigma**2)).astype(np.float32))


# ----------------------------------------------------------------- fMRI
def test_reorient_artifacts_shapes():
    rng = np.random.default_rng(0)
    v = _vol(rng)
    for fn in (M.fmri_reorient_x, M.fmri_reorient_y, M.fmri_reorient_z):
        (out,) = fn(v)
        assert out.shape == shapes.VOLUME


def test_alignlinear_identity_for_same_volume():
    v = _gaussian_vol((32, 32, 12))
    (p,) = M.fmri_alignlinear(v, v)
    np.testing.assert_allclose(p, [1, 0, 1, 0, 1, 0], atol=1e-3)


def test_alignlinear_recovers_known_shift():
    """A volume shifted by +4 voxels in x must yield tx ~ 4, sx ~ 1."""
    ref = _gaussian_vol((30, 32, 12))
    moved = _gaussian_vol((34, 32, 12))
    (p,) = M.fmri_alignlinear(moved, ref)
    assert p[0] == pytest.approx(1.0, abs=0.05)  # sx
    assert p[1] == pytest.approx(4.0, abs=0.3)  # tx
    assert p[3] == pytest.approx(0.0, abs=0.3)  # ty


def test_align_then_reslice_reduces_misalignment():
    ref = _gaussian_vol((30, 32, 12))
    moved = _gaussian_vol((35, 34, 12))
    (p,) = M.fmri_alignlinear(moved, ref)
    (resliced,) = M.fmri_reslice(moved, p)
    before = float(jnp.sum((moved - ref) ** 2))
    after = float(jnp.sum((resliced - ref) ** 2))
    assert after < 0.25 * before


def test_fmri_chain_matches_staged_pipeline():
    """The fused clustering chain equals the four staged artifacts."""
    rng = np.random.default_rng(1)
    vol, ref = _vol(rng), _gaussian_vol((32, 32, 12))
    chained, cp = M.fmri_volume_chain(vol, ref)
    (v1,) = M.fmri_reorient_y(vol)
    (v2,) = M.fmri_reorient_x(v1)
    (r1,) = M.fmri_reorient_y(ref)
    (r2,) = M.fmri_reorient_x(r1)
    (p,) = M.fmri_alignlinear(v2, r2)
    (staged,) = M.fmri_reslice(v2, p)
    np.testing.assert_allclose(cp, p, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(chained, staged, rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------- Montage
def test_difffit_recovers_plane():
    """If a - b is exactly a plane, the fit recovers its coefficients."""
    h, w = shapes.IMAGE_SMALL
    ri = np.arange(h, dtype=np.float32)[:, None]
    ci = np.arange(w, dtype=np.float32)[None, :]
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.normal(size=(h, w)).astype(np.float32))
    plane = 3.0 + 0.01 * ri - 0.02 * ci
    a = b + jnp.asarray(plane)
    _, coeffs = M.montage_difffit(a, b)
    np.testing.assert_allclose(coeffs, [3.0, 0.01, -0.02], rtol=1e-2, atol=1e-3)


def test_bgcorrect_removes_fitted_plane():
    h, w = shapes.IMAGE_SMALL
    rng = np.random.default_rng(3)
    img = jnp.asarray(rng.normal(size=(h, w)).astype(np.float32))
    ri = np.arange(h, dtype=np.float32)[:, None]
    ci = np.arange(w, dtype=np.float32)[None, :]
    tilted = img + jnp.asarray(5.0 + 0.02 * ri + 0.01 * ci)
    _, coeffs = M.montage_difffit(tilted, img)
    (fixed,) = M.montage_bgcorrect(tilted, coeffs)
    np.testing.assert_allclose(fixed, img, rtol=1e-2, atol=1e-2)


def test_project_coadd_roundtrip_mean():
    """Co-adding K identical projections returns the projection."""
    rng = np.random.default_rng(4)
    h, w = shapes.IMAGE_SMALL
    img = jnp.asarray(rng.normal(size=(h, w)).astype(np.float32))
    p = jnp.array([1.0, 0.0, 1.0, 0.0], jnp.float32)
    (proj,) = M.montage_project(img, p)
    stack = jnp.stack([proj] * shapes.COADD_K)
    weights = jnp.ones((shapes.COADD_K,), jnp.float32)
    (mosaic,) = M.montage_coadd(stack, weights)
    np.testing.assert_allclose(mosaic, proj, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- MolDyn
def _ligand(rng, n=shapes.ATOMS):
    side = int(np.ceil(n ** (1 / 3)))
    g = np.stack(
        np.meshgrid(*[np.arange(side)] * 3, indexing="ij"), -1
    ).reshape(-1, 3)[:n]
    return jnp.asarray(
        (g * 1.15 + rng.normal(scale=0.04, size=(n, 3))).astype(np.float32)
    )


def test_equilibrate_reduces_energy():
    rng = np.random.default_rng(5)
    pos = _ligand(rng)
    _, e0 = M.moldyn_energy(pos)
    pos1, _ = M.moldyn_equilibrate(pos)
    _, e1 = M.moldyn_energy(pos1)
    assert float(e1[0]) < float(e0[0])


def test_equilibrate_preserves_shape_and_finiteness():
    rng = np.random.default_rng(6)
    pos1, e = M.moldyn_equilibrate(_ligand(rng))
    assert pos1.shape == (shapes.ATOMS, 3)
    assert np.isfinite(np.asarray(pos1)).all()
    assert np.isfinite(float(e[0]))


def test_wham_converges_to_fixed_point():
    rng = np.random.default_rng(7)
    s, b = shapes.WHAM_STATES, shapes.WHAM_BINS
    counts = jnp.abs(jnp.asarray(rng.normal(size=(1, b)).astype(np.float32))) + 1.0
    bias = jnp.asarray((rng.normal(size=(s, b)) * 0.5).astype(np.float32))
    nsamp = jnp.ones((s, 1), jnp.float32) * 100.0
    f, p = M.moldyn_wham(counts, bias, nsamp)
    # One more iteration barely moves the solution.
    from compile.kernels import wham_iterate

    f2, _ = wham_iterate(counts, bias, nsamp, f)
    np.testing.assert_allclose(f, f2, atol=5e-3)
    assert float(f[0, 0]) == 0.0


def test_artifact_registry_is_complete_and_lowerable_shapes():
    """Every artifact's fn accepts its declared specs (abstract eval)."""
    import jax

    for name, (fn, specs) in M.ARTIFACTS.items():
        out = jax.eval_shape(fn, *specs)
        assert isinstance(out, tuple) and len(out) >= 1, name
