"""AOT path tests: lowering produces parseable HLO text + valid manifest."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


def test_to_hlo_text_contains_entry():
    lowered = jax.jit(lambda x: (x + 1.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text


def test_hlo_text_is_tupled():
    """return_tuple=True: root instruction is a tuple (rust unwraps it)."""
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "tuple(" in text or "(f32[2,2]" in text


def test_lower_artifact_writes_file_and_manifest_line(tmp_path):
    line = aot.lower_artifact("reorient_y", str(tmp_path))
    assert (tmp_path / "reorient_y.hlo.txt").exists()
    assert line.startswith("reorient_y ")
    assert "inputs=f32[64,64,24]" in line
    assert "outputs=f32[64,64,24]" in line


def test_manifest_format_roundtrip(tmp_path):
    """Manifest lines parse into (name, inputs, outputs) triples the way
    the Rust ArtifactRegistry parses them."""
    line = aot.lower_artifact("wham", str(tmp_path))
    name, ins, outs = line.split(" ")
    assert name == "wham"
    assert ins.removeprefix("inputs=").split(";") == [
        "f32[1,64]",
        "f32[8,64]",
        "f32[8,1]",
    ]
    assert outs.removeprefix("outputs=").split(";") == [
        "f32[8,1]",
        "f32[1,64]",
    ]


def test_every_artifact_has_fixed_f32_shapes():
    for name, (_fn, specs) in model.ARTIFACTS.items():
        for s in specs:
            assert s.dtype == jnp.float32, name
            assert all(isinstance(d, int) for d in s.shape), name


@pytest.mark.slow
def test_full_artifact_build_matches_registry(tmp_path):
    """Lower everything (as `make artifacts` does) and check the manifest
    covers the registry exactly."""
    for name in model.ARTIFACTS:
        aot.lower_artifact(name, str(tmp_path))
    files = {f.removesuffix(".hlo.txt") for f in os.listdir(tmp_path)}
    assert files == set(model.ARTIFACTS)
