"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and where meaningful, block sizes and parameter
ranges); fixed-seed numpy data keeps runs deterministic. This is the core
correctness signal for the kernels the Rust runtime executes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref as R

SET = dict(max_examples=12, deadline=None)


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------- matmul
@settings(**SET)
@given(
    m=st.sampled_from([8, 32, 64, 96]),
    k=st.sampled_from([8, 32, 64, 128]),
    n=st.sampled_from([8, 32, 64]),
    bm=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, bm, seed):
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, m, k), _rand(rng, k, n)
    got = K.matmul(x, y, bm=bm, bk=bm, bn=bm)
    want = R.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_rejects_mismatched_contraction():
    x = jnp.zeros((4, 5), jnp.float32)
    y = jnp.zeros((6, 4), jnp.float32)
    with pytest.raises(AssertionError):
        K.matmul(x, y)


# -------------------------------------------------------------- reorient
@settings(**SET)
@given(
    x=st.sampled_from([8, 16, 64]),
    y=st.sampled_from([8, 16, 64]),
    z=st.sampled_from([4, 8, 24]),
    axis=st.sampled_from([0, 1, 2]),
    bz=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_reorient_matches_flip(x, y, z, axis, bz, seed):
    rng = np.random.default_rng(seed)
    v = _rand(rng, x, y, z)
    got = K.reorient(v, axis=axis, bz=bz)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(R.reorient_ref(v, axis)))


def test_reorient_involution():
    rng = np.random.default_rng(7)
    v = _rand(rng, 16, 16, 8)
    for axis in range(3):
        np.testing.assert_array_equal(
            np.asarray(K.reorient(K.reorient(v, axis=axis), axis=axis)),
            np.asarray(v),
        )


# --------------------------------------------------------------- moments
@settings(**SET)
@given(
    x=st.sampled_from([8, 16, 64]),
    z=st.sampled_from([4, 8, 24]),
    bz=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_moments_matches_ref(x, z, bz, seed):
    rng = np.random.default_rng(seed)
    # Non-negative weights, as in intensity images.
    v = jnp.abs(_rand(rng, x, x, z))
    got = K.moments(v, bz=bz)
    want = R.moments_ref(v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-2)


def test_moments_point_mass():
    """A single bright voxel: moments are its coordinates exactly."""
    v = np.zeros((8, 8, 8), np.float32)
    v[3, 5, 6] = 2.0
    m = np.asarray(K.moments(jnp.asarray(v)))
    assert m[0] == pytest.approx(2.0)
    np.testing.assert_allclose(m[1:4] / m[0], [3.0, 5.0, 6.0])


# -------------------------------------------------- mproject / reslice
@settings(**SET)
@given(
    h=st.sampled_from([32, 64, 128]),
    sr=st.floats(0.5, 1.8),
    tr=st.floats(-4.0, 4.0),
    sc=st.floats(0.5, 1.8),
    tc=st.floats(-4.0, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_mproject_matches_ref(h, sr, tr, sc, tc, seed):
    rng = np.random.default_rng(seed)
    img = _rand(rng, h, h)
    p = jnp.array([sr, tr, sc, tc], jnp.float32)
    np.testing.assert_allclose(
        K.mproject(img, p), R.mproject_ref(img, p), rtol=1e-3, atol=1e-3
    )


def test_mproject_identity():
    rng = np.random.default_rng(3)
    img = _rand(rng, 64, 64)
    p = jnp.array([1.0, 0.0, 1.0, 0.0], jnp.float32)
    np.testing.assert_allclose(K.mproject(img, p), img, rtol=1e-5, atol=1e-5)


@settings(**SET)
@given(
    seed=st.integers(0, 2**31 - 1),
    sx=st.floats(0.7, 1.4),
    tx=st.floats(-2.0, 2.0),
)
def test_reslice_matches_ref(seed, sx, tx):
    rng = np.random.default_rng(seed)
    v = _rand(rng, 16, 16, 8)
    p = jnp.array([sx, tx, 1.1, -0.5, 0.9, 0.25], jnp.float32)
    np.testing.assert_allclose(
        K.reslice(v, p), R.reslice_ref(v, p), rtol=1e-3, atol=1e-3
    )


def test_reslice_identity():
    rng = np.random.default_rng(4)
    v = _rand(rng, 16, 16, 8)
    p = jnp.array([1, 0, 1, 0, 1, 0], jnp.float32)
    np.testing.assert_allclose(K.reslice(v, p), v, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- difffit
@settings(**SET)
@given(
    h=st.sampled_from([32, 64, 128]),
    br=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_difffit_matches_ref(h, br, seed):
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, h, h), _rand(rng, h, h)
    d1, s1 = K.difffit(a, b, br=br)
    d2, s2 = R.difffit_ref(a, b)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=0.5)


def test_difffit_zero_for_identical():
    rng = np.random.default_rng(5)
    a = _rand(rng, 32, 32)
    d, s = K.difffit(a, a)
    assert float(jnp.max(jnp.abs(d))) == 0.0
    np.testing.assert_array_equal(np.asarray(s), np.zeros(4, np.float32))


# ------------------------------------------------------------------ coadd
@settings(**SET)
@given(
    k=st.sampled_from([2, 4, 8]),
    h=st.sampled_from([32, 64]),
    br=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_coadd_matches_ref(k, h, br, seed):
    rng = np.random.default_rng(seed)
    stack = _rand(rng, k, h, h)
    w = jnp.abs(_rand(rng, k)) + 0.1
    np.testing.assert_allclose(
        K.coadd(stack, w, br=br), R.coadd_ref(stack, w), rtol=1e-4, atol=1e-4
    )


def test_coadd_single_image_passthrough():
    """With all weight on one image the coadd returns that image."""
    rng = np.random.default_rng(6)
    stack = _rand(rng, 4, 16, 16)
    w = jnp.array([0.0, 0.0, 1.0, 0.0], jnp.float32)
    np.testing.assert_allclose(K.coadd(stack, w), stack[2], rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- mdenergy
def _lattice(rng, n):
    side = int(np.ceil(n ** (1 / 3)))
    g = np.stack(
        np.meshgrid(*[np.arange(side)] * 3, indexing="ij"), -1
    ).reshape(-1, 3)[:n]
    return jnp.asarray(
        (g * 1.1 + rng.normal(scale=0.05, size=(n, 3))).astype(np.float32)
    )


@settings(**SET)
@given(
    n=st.sampled_from([32, 64, 128]),
    br=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mdenergy_matches_ref(n, br, seed):
    rng = np.random.default_rng(seed)
    pos = _lattice(rng, n)
    f1, e1 = K.mdenergy(pos, br=br)
    f2, e2 = R.mdenergy_ref(pos)
    fscale = float(jnp.max(jnp.abs(f2))) + 1.0
    np.testing.assert_allclose(f1, f2, rtol=1e-3, atol=1e-4 * fscale)
    np.testing.assert_allclose(e1, e2, rtol=1e-4)


def test_mdenergy_forces_sum_to_zero():
    """Newton's third law: internal forces cancel."""
    rng = np.random.default_rng(8)
    pos = _lattice(rng, 64)
    f, _ = K.mdenergy(pos)
    np.testing.assert_allclose(jnp.sum(f, axis=0), jnp.zeros(3), atol=5e-3)


def test_mdenergy_two_atoms_at_minimum():
    """At r = 2^(1/6) sigma the LJ force vanishes and e = -eps per pair."""
    r0 = 2.0 ** (1.0 / 6.0)
    pos = jnp.array([[0, 0, 0], [r0, 0, 0]], jnp.float32)
    f, e = K.mdenergy(pos, br=1)
    assert float(e) == pytest.approx(-1.0, rel=1e-4)
    np.testing.assert_allclose(f, np.zeros((2, 3)), atol=1e-4)


# ------------------------------------------------------------------- wham
@settings(**SET)
@given(
    s=st.sampled_from([2, 4, 8]),
    b=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_wham_matches_ref(s, b, seed):
    rng = np.random.default_rng(seed)
    counts = jnp.abs(_rand(rng, 1, b)) + 0.1
    bias = _rand(rng, s, b)
    nsamp = jnp.abs(_rand(rng, s, 1)) + 1.0
    f = _rand(rng, s, 1)
    f1, p1 = K.wham_iterate(counts, bias, nsamp, f)
    f2, p2 = R.wham_iterate_ref(counts, bias, nsamp, f)
    np.testing.assert_allclose(f1, f2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(p1, p2, rtol=1e-4, atol=1e-6)


def test_wham_gauge_anchor():
    """Output free energies are anchored at f[0] == 0."""
    rng = np.random.default_rng(9)
    counts = jnp.abs(_rand(rng, 1, 16)) + 0.1
    bias = _rand(rng, 4, 16)
    nsamp = jnp.ones((4, 1), jnp.float32)
    f, _ = K.wham_iterate(counts, bias, nsamp, jnp.zeros((4, 1), jnp.float32))
    assert float(f[0, 0]) == 0.0
