// GENATLAS2 (paper Table 1): GENATLAS1 plus axial/sagittal/coronal
// snapshots of the atlas rendered to image files.
type Image {};
type Header {};
type Volume { Image img; Header hdr; };
type Run { Volume v[]; };
type Air {};

(Air a) alignlinear (Volume std, Volume iv, int model) {
  app { alignlinear @filename(std.img) @filename(iv.img) @filename(a) model; }
}
(Volume ov) reslice (Volume iv, Air air) {
  app { reslice @filename(air) @filename(iv.img) @filename(ov.img); }
}
(Volume atlas) softmean (Run r) {
  app { softmean @filename(atlas.img) @filename(atlas.hdr) "y" @filenames(r.v); }
}
(Image s) slicer (Volume iv, string axis, float position) {
  app { slicer @filename(iv.img) axis position @filename(s); }
}
(Image png) convert (Image ppm) {
  app { convert @filename(ppm) @filename(png); }
}
(Volume atlas) genatlas (Run r) {
  Volume std = r.v[0];
  Run aligned;
  foreach Volume iv, i in r.v {
    Air a = alignlinear(std, iv, 12);
    aligned.v[i] = reslice(iv, a);
  }
  atlas = softmean(aligned);
}

Run anatomies<run_mapper;location="data/anatomy",prefix="anat">;
Volume atlas2<run_mapper;location="results",prefix="atlas2">;
atlas2 = genatlas(anatomies);
Image axial = convert(slicer(atlas2, "x", 0.5));
Image sagittal = convert(slicer(atlas2, "y", 0.5));
Image coronal = convert(slicer(atlas2, "z", 0.5));
