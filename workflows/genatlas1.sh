#!/bin/sh
# GENATLAS1 as an ad-hoc shell script (paper Table 1 comparison point):
# fixed file layout, fixed volume count, serial execution, no typing,
# no restart. Compare workflows/genatlas1.swift.
set -e
DATA=data/anatomy
OUT=results
MODEL=12
mkdir -p "$OUT" work
STD_IMG=$DATA/anat_0000.img
STD_HDR=$DATA/anat_0000.hdr
i=0
for img in "$DATA"/anat_*.img; do
  base=$(basename "$img" .img)
  hdr=$DATA/$base.hdr
  if [ ! -f "$hdr" ]; then
    echo "missing header for $base" >&2
    exit 1
  fi
  air=work/$base.air
  alignlinear "$STD_IMG" "$img" "$air" -m $MODEL || exit 1
  reslice "$air" "$img" work/aligned_$(printf '%04d' $i).img
  cp "$hdr" work/aligned_$(printf '%04d' $i).hdr
  i=$((i + 1))
done
if [ $i -eq 0 ]; then
  echo "no input volumes in $DATA" >&2
  exit 1
fi
softmean "$OUT/atlas1.img" "$OUT/atlas1.hdr" y work/aligned_*.img
echo "atlas written to $OUT/atlas1.img ($i volumes)"
