#!/usr/bin/perl
# GENATLAS1 DAG generator (paper Table 1 comparison point): emits a
# Condor DAGMan file plus one submit file per job. The workflow shape
# is hard-coded here instead of being derived from the data, which is
# the brittleness SwiftScript removes.
use strict;
use warnings;

my $data  = shift @ARGV || "data/anatomy";
my $out   = shift @ARGV || "results";
my $model = 12;

opendir(my $dh, $data) or die "cannot open $data: $!";
my @imgs = sort grep { /^anat_\d+\.img$/ } readdir($dh);
closedir($dh);
die "no input volumes in $data" unless @imgs;

my $std = "$data/$imgs[0]";
open(my $dag, ">", "genatlas1.dag") or die $!;
my @reslice_jobs;

sub submit_file {
    my ($name, $exe, @args) = @_;
    open(my $fh, ">", "$name.sub") or die $!;
    print $fh "executable = $exe\n";
    print $fh "arguments  = @args\n";
    print $fh "error      = $name.err\n";
    print $fh "queue\n";
    close($fh);
}

my $i = 0;
for my $img (@imgs) {
    (my $base = $img) =~ s/\.img$//;
    my $air     = "work/$base.air";
    my $aligned = sprintf("work/aligned_%04d.img", $i);
    submit_file("align_$i", "alignlinear", "$std", "$data/$img", $air, "-m", $model);
    submit_file("reslice_$i", "reslice", $air, "$data/$img", $aligned);
    print $dag "JOB align_$i align_$i.sub\n";
    print $dag "JOB reslice_$i reslice_$i.sub\n";
    print $dag "PARENT align_$i CHILD reslice_$i\n";
    push @reslice_jobs, "reslice_$i";
    $i++;
}
submit_file("softmean", "softmean", "$out/atlas1.img", "$out/atlas1.hdr", "y",
    map { sprintf("work/aligned_%04d.img", $_) } 0 .. $i - 1);
print $dag "JOB softmean softmean.sub\n";
print $dag "PARENT @reslice_jobs CHILD softmean\n";
close($dag);
print "wrote genatlas1.dag with ", 2 * $i + 1, " jobs\n";
