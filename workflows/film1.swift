// FILM1 (paper Table 1): FSL FILM general-linear-model fit over a
// preprocessed BOLD run against a design matrix.
type Image {};
type Header {};
type Design {};
type Volume { Image img; Header hdr; };
type Run { Volume v[]; };
type Stats { Image pe; Image res; };

(Volume ov) smooth (Volume iv, float fwhm) {
  app { susan @filename(iv.img) fwhm @filename(ov.img); }
}
(Run or) smoothRun (Run ir, float fwhm) {
  foreach Volume iv, i in ir.v {
    or.v[i] = smooth(iv, fwhm);
  }
}
(Stats s) film (Run r, Design d) {
  app {
    film_gls @filename(d) @filename(s.pe) @filename(s.res) @filenames(r.v);
  }
}

Design design<file_mapper;file="design/design.mat">;
Run bold<run_mapper;location="data/func",prefix="bold1">;
Stats stats1<run_mapper;location="results",prefix="stats1">;
Run sbold = smoothRun(bold, 5.0);
stats1 = film(sbold, design);
