#!/bin/sh
# AIRSN as an ad-hoc shell script (paper Table 1 comparison point).
# Serial, fixed layout, manual bookkeeping of intermediate names at
# every stage — compare workflows/airsn.swift.
set -e
DATA=data/func
ATLAS=data/atlas/atlas.img
OUT=results
MODEL=12
mkdir -p "$OUT" work/yro work/ro work/air work/resliced work/snorm

# Stage 1+2: reorient twice.
for img in "$DATA"/bold1_*.img; do
  base=$(basename "$img" .img)
  reorient "$img" work/yro/$base.img y n
  cp "$DATA/$base.hdr" work/yro/$base.hdr
done
for img in work/yro/*.img; do
  base=$(basename "$img" .img)
  reorient "$img" work/ro/$base.img x n
  cp work/yro/$base.hdr work/ro/$base.hdr
done

# Stage 3: motion correction against the first volume.
STD=$(ls work/ro/*.img | head -n 1)
for img in work/ro/*.img; do
  base=$(basename "$img" .img)
  alignlinear "$STD" "$img" work/air/$base.air -m $MODEL -t1 1000 -t2 1000 -b1 81 3 3
done

# Stage 4: reslice with the recorded transforms.
for img in work/ro/*.img; do
  base=$(basename "$img" .img)
  reslice work/air/$base.air "$img" work/resliced/$base.img -o -k
  cp work/ro/$base.hdr work/resliced/$base.hdr
done

# Stage 5: mean volume.
softmean work/mean.img work/mean.hdr y work/resliced/*.img

# Stage 6: warp to atlas space, apply to every volume.
align_warp "$ATLAS" work/mean.img work/mean.warp -m $MODEL
for img in work/resliced/*.img; do
  base=$(basename "$img" .img)
  reslice_warp work/mean.warp "$img" work/snorm/$base.img
  cp work/resliced/$base.hdr work/snorm/$base.hdr
done

# Stage 7: snapshots + publish.
FIRST=$(ls work/snorm/*.img | head -n 1)
slicer "$FIRST" x 0.5 "$OUT/axial.ppm"
slicer "$FIRST" y 0.5 "$OUT/sagittal.ppm"
cp work/snorm/*.img work/snorm/*.hdr "$OUT"/
echo "spatially normalized run published to $OUT"
