// GENATLAS1 (paper Table 1, smallest workflow): align each anatomical
// volume to a reference, reslice, and average into an atlas.
type Image {};
type Header {};
type Volume { Image img; Header hdr; };
type Run { Volume v[]; };
type Air {};

(Air a) alignlinear (Volume std, Volume iv, int model) {
  app { alignlinear @filename(std.img) @filename(iv.img) @filename(a) model; }
}
(Volume ov) reslice (Volume iv, Air air) {
  app { reslice @filename(air) @filename(iv.img) @filename(ov.img); }
}
(Volume atlas) softmean (Run r) {
  app { softmean @filename(atlas.img) @filename(atlas.hdr) "y" @filenames(r.v); }
}
(Volume atlas) genatlas (Run r) {
  Volume std = r.v[0];
  Run aligned;
  foreach Volume iv, i in r.v {
    Air a = alignlinear(std, iv, 12);
    aligned.v[i] = reslice(iv, a);
  }
  atlas = softmean(aligned);
}

Run anatomies<run_mapper;location="data/anatomy",prefix="anat">;
Volume atlas1<run_mapper;location="results",prefix="atlas1">;
atlas1 = genatlas(anatomies);
