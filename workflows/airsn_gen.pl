#!/usr/bin/perl
# AIRSN DAG generator (paper Table 1 comparison point): emits DAGMan
# files for the seven-stage spatial-normalization pipeline. Every stage
# boundary and file name convention is replicated by hand; changing the
# pipeline means editing both this generator and its downstream
# consumers, which is the maintenance cost Table 1 quantifies.
use strict;
use warnings;

my $data  = shift @ARGV || "data/func";
my $atlas = shift @ARGV || "data/atlas/atlas.img";
my $out   = shift @ARGV || "results";
my $model = 12;

opendir(my $dh, $data) or die "cannot open $data: $!";
my @imgs = sort grep { /^bold1_\d+\.img$/ } readdir($dh);
closedir($dh);
die "no volumes in $data" unless @imgs;
my $n = scalar @imgs;

open(my $dag, ">", "airsn.dag") or die $!;

sub submit_file {
    my ($name, $exe, @args) = @_;
    open(my $fh, ">", "$name.sub") or die $!;
    print $fh "executable = $exe\n";
    print $fh "arguments  = @args\n";
    print $fh "error      = $name.err\n";
    print $fh "queue\n";
    close($fh);
    print $dag "JOB $name $name.sub\n";
}

my (@yro, @ro, @air, @resl, @warp);
for my $i (0 .. $n - 1) {
    my $base = sprintf("bold1_%04d", $i);
    submit_file("yro_$i", "reorient", "$data/$base.img", "work/yro/$base.img", "y", "n");
    submit_file("ro_$i", "reorient", "work/yro/$base.img", "work/ro/$base.img", "x", "n");
    print $dag "PARENT yro_$i CHILD ro_$i\n";
    push @yro, "yro_$i";
    push @ro,  "ro_$i";
}
my $std = "work/ro/bold1_0000.img";
for my $i (0 .. $n - 1) {
    my $base = sprintf("bold1_%04d", $i);
    submit_file("air_$i", "alignlinear", $std, "work/ro/$base.img",
        "work/air/$base.air", "-m", $model, "-t1", 1000, "-t2", 1000);
    print $dag "PARENT ro_$i ro_0 CHILD air_$i\n";
    submit_file("resl_$i", "reslice", "work/air/$base.air",
        "work/ro/$base.img", "work/resliced/$base.img", "-o", "-k");
    print $dag "PARENT air_$i CHILD resl_$i\n";
    push @air,  "air_$i";
    push @resl, "resl_$i";
}
submit_file("mean", "softmean", "work/mean.img", "work/mean.hdr", "y",
    map { sprintf("work/resliced/bold1_%04d.img", $_) } 0 .. $n - 1);
print $dag "PARENT @resl CHILD mean\n";
submit_file("warp", "align_warp", $atlas, "work/mean.img", "work/mean.warp", "-m", $model);
print $dag "PARENT mean CHILD warp\n";
for my $i (0 .. $n - 1) {
    my $base = sprintf("bold1_%04d", $i);
    submit_file("snorm_$i", "reslice_warp", "work/mean.warp",
        "work/resliced/$base.img", "work/snorm/$base.img");
    print $dag "PARENT warp CHILD snorm_$i\n";
    push @warp, "snorm_$i";
}
submit_file("axial", "slicer", "work/snorm/bold1_0000.img", "x", 0.5, "$out/axial.ppm");
submit_file("sagittal", "slicer", "work/snorm/bold1_0000.img", "y", 0.5, "$out/sagittal.ppm");
print $dag "PARENT snorm_0 CHILD axial sagittal\n";
close($dag);
print "wrote airsn.dag with ", 4 * $n + 4, " jobs\n";
