// FEAT (paper Table 1): FSL first-level analysis — brain extraction,
// motion correction, optional smoothing, model fit, post-stats.
type Image {};
type Header {};
type Design {};
type Volume { Image img; Header hdr; };
type Run { Volume v[]; };
type Stats { Image pe; Image res; };
type Report { Image zstat; Image rendered; };

(Volume ov) bet (Volume iv, float frac) {
  app { bet @filename(iv.img) frac @filename(ov.img); }
}
(Volume ov) mcflirt (Volume iv, Volume reference) {
  app { mcflirt @filename(iv.img) @filename(reference.img) @filename(ov.img); }
}
(Volume ov) smooth (Volume iv, float fwhm) {
  app { susan @filename(iv.img) fwhm @filename(ov.img); }
}
(Run or) preprocess (Run ir, float frac, float fwhm) {
  Volume reference = ir.v[0];
  foreach Volume iv, i in ir.v {
    Volume stripped = bet(iv, frac);
    Volume moved = mcflirt(stripped, reference);
    or.v[i] = smooth(moved, fwhm);
  }
}
(Stats s) film (Run r, Design d) {
  app {
    film_gls @filename(d) @filename(s.pe) @filename(s.res) @filenames(r.v);
  }
}
(Report rep) poststats (Stats s, float zthresh) {
  app {
    cluster @filename(s.pe) @filename(s.res) zthresh
      @filename(rep.zstat) @filename(rep.rendered);
  }
}

Design design<file_mapper;file="design/design.mat">;
Run bold<run_mapper;location="data/func",prefix="bold1">;
Report report<run_mapper;location="results",prefix="feat1">;
int smoothmm = 5;
Run pre;
if (smoothmm > 0) {
  pre = preprocess(bold, 0.3, 5.0);
} else {
  pre = preprocess(bold, 0.3, 0.0);
}
Stats stats = film(pre, design);
report = poststats(stats, 2.3);
