// AIRSN (paper Table 1, largest workflow): AIR spatial normalization —
// reorient twice, motion-correct every volume against a reference,
// reslice, average into a mean volume, warp to the atlas space, and
// render snapshot images.
type Image {};
type Header {};
type Volume { Image img; Header hdr; };
type Run { Volume v[]; };
type Air {};
type AirVector { Air a[]; };
type Warp {};

(Volume ov) reorient (Volume iv, string direction, string overwrite) {
  app { reorient @filename(iv.img) @filename(ov.img) direction overwrite; }
}
(Air out) alignlinear (Volume std, Volume iv, int m, int x, int y, string opts) {
  app { alignlinear @filename(std.img) @filename(iv.img) @filename(out) m x y opts; }
}
(Volume ov) reslice (Volume iv, Air align, string o, string k) {
  app { reslice @filename(align) @filename(iv.img) @filename(ov.img) o k; }
}
(Run or) reorientRun (Run ir, string direction, string overwrite) {
  foreach Volume iv, i in ir.v {
    or.v[i] = reorient(iv, direction, overwrite);
  }
}
(AirVector ov) alignlinearRun (Volume std, Run ir, int m, int x, int y, string opts) {
  foreach Volume iv, i in ir.v {
    ov.a[i] = alignlinear(std, iv, m, x, y, opts);
  }
}
(Run or) resliceRun (Run ir, AirVector av, string o, string k) {
  foreach Volume iv, i in ir.v {
    or.v[i] = reslice(iv, av.a[i], o, k);
  }
}
(Volume mean) softmean (Run r) {
  app { softmean @filename(mean.img) @filename(mean.hdr) "y" @filenames(r.v); }
}
(Warp w) alignwarp (Volume atlas, Volume mean, string model) {
  app { align_warp @filename(atlas.img) @filename(mean.img) @filename(w) model; }
}
(Volume ov) resliceWarp (Volume iv, Warp w) {
  app { reslice_warp @filename(w) @filename(iv.img) @filename(ov.img); }
}
(Image s) slicer (Volume iv, string axis, float position) {
  app { slicer @filename(iv.img) axis position @filename(s); }
}
(Run snorm) airsn (Run r, Volume atlas) {
  Run yroRun = reorientRun(r, "y", "n");
  Run roRun = reorientRun(yroRun, "x", "n");
  Volume std = roRun.v[0];
  AirVector roAirVec = alignlinearRun(std, roRun, 12, 1000, 1000, "81 3 3");
  Run reslicedRun = resliceRun(roRun, roAirVec, "-o", "-k");
  Volume mean = softmean(reslicedRun);
  Warp warp = alignwarp(atlas, mean, "12");
  foreach Volume iv, i in reslicedRun.v {
    snorm.v[i] = resliceWarp(iv, warp);
  }
}

Volume atlas<run_mapper;location="data/atlas",prefix="atlas">;
Run bold1<run_mapper;location="data/func",prefix="bold1">;
Run snbold1<run_mapper;location="results",prefix="snbold1">;
snbold1 = airsn(bold1, atlas);
Volume check = snbold1.v[0];
Image axial = slicer(check, "x", 0.5);
Image sagittal = slicer(check, "y", 0.5);
