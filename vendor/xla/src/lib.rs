//! Offline stub of the `xla` (PJRT) binding API that
//! `gridswift::runtime` compiles against.
//!
//! The real binding wraps the xla_extension C++ library, which is not
//! available in this build environment. This stub provides the exact
//! API surface the runtime uses so the whole workspace builds and
//! tests run; every entry point that would touch PJRT returns a
//! descriptive [`Error`] at runtime instead. Integration tests that
//! need real artifacts skip themselves when the artifact directory is
//! absent, so the stub never executes in CI.
//!
//! Swap this path dependency for the real `xla` crate (and build
//! artifacts with `python/compile/aot.py`) to enable the compute path.

use std::fmt;
use std::path::Path;

/// Error type mirroring the binding's: a displayable message.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla backend unavailable ({what}): this build uses the offline stub \
         in vendor/xla; link the real xla/PJRT binding to execute artifacts"
    )))
}

/// PJRT client handle (stub).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Host-side literal value (stub).
pub struct Literal(());

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla backend unavailable"));
    }

    #[test]
    fn computation_wrapping_is_inert() {
        // from_proto takes a reference; constructing the input requires
        // a (failing) parse, so only the error path is reachable here.
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
    }
}
