//! Offline-buildable subset of the `anyhow` error-handling API.
//!
//! The build environment for this repository has no network access to
//! crates.io, so the handful of `anyhow` features the codebase uses are
//! vendored here behind the same names: [`Error`], [`Result`], the
//! [`anyhow!`], [`bail!`] and [`ensure!`] macros, and the [`Context`]
//! extension trait for `Result` and `Option`.
//!
//! Error values are stored as a flattened context chain of strings:
//! `Display` prints the outermost message, `{:#}` prints the whole chain
//! separated by `": "` (matching anyhow's alternate formatting, which the
//! codebase relies on when rendering task failures), and `Debug` prints
//! the chain in anyhow's `Caused by:` layout.

use std::fmt;

/// `Result` with a defaulted error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chain error type mirroring the parts of `anyhow::Error` the
/// repository uses.
pub struct Error {
    /// Outermost message first; each added context pushes to the front.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Construct from a standard error, capturing its source chain.
    pub fn new<E>(error: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, outermost first.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

mod ext {
    use super::Error;

    /// Private conversion trait so [`super::Context`] works uniformly on
    /// `Result<T, E: std::error::Error>` and `Result<T, Error>` (the same
    /// device real anyhow uses; `Error` itself does not implement
    /// `std::error::Error`, so the impls do not overlap).
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::new(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, like `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| ext::IntoError::into_error(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| ext::IntoError::into_error(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string, like `anyhow::anyhow!`.
///
/// Tokens are forwarded verbatim to `format!`, so positional arguments
/// and inline captures (`anyhow!("missing {name}")`) behave exactly as
/// they do in `format!`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(::std::format!($($arg)+))
    };
}

/// Early-return with an error, like `anyhow::bail!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Assert a condition, early-returning an error on failure, like
/// `anyhow::ensure!`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
    }

    #[test]
    fn context_on_io_error() {
        let r: Result<String> =
            std::fs::read_to_string("/definitely/not/here").context("reading config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").starts_with("reading config: "));
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(format!("{e}"), "missing x");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn go() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(go().is_err());
    }

    #[test]
    fn ensure_formats_message() {
        fn go(n: u32) -> Result<()> {
            ensure!(n > 3, "n too small: {n}");
            Ok(())
        }
        assert!(go(5).is_ok());
        assert_eq!(format!("{}", go(1).unwrap_err()), "n too small: 1");
    }

    #[test]
    fn anyhow_macro_inline_captures() {
        let what = "thing";
        let e = anyhow!("missing {what} ({})", 42);
        assert_eq!(format!("{e}"), "missing thing (42)");
    }
}
